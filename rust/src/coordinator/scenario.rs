//! Phase-shifting scenario replay: the workload side of the QoR governor
//! (`rapid serve-bench --governor`, `make bench-governor`,
//! `tests/governor_e2e.rs`).
//!
//! A scenario is a list of [`Phase`]s, each pairing an operand [`Regime`]
//! (clean = narrow operands whose approximate products barely err; noisy
//! = full-width operands that expose the cheap rungs) with a request
//! count and an offered rate. The runner ([`run_scenario`]) drives a
//! governed coordinator open-loop through the phases — the paper apps
//! become long-running adaptive workloads whose QoR-vs-throughput traces
//! land in `BENCH_governor.json` / EXPERIMENTS.md §Governor.
//!
//! Determinism contract: operands are a pure function of
//! `(seed, request index, regime)`, windows close on request *count* (not
//! time), QoR is shadow-computed from seeded samples, and the governor is
//! a pure state machine — so the recorded switch trace (and, with no
//! shedding, the response checksum) is bit-identical across
//! `RAPID_THREADS`, shard counts and machines. Wall-clock pacing only
//! affects the latency columns.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::governor::{App, Governor, GovernorConfig, GovernorTrace, Ladder, WindowAccumulator, WindowObs, is_sampled};
use super::loadgen::request_digest;
use super::metrics::PhaseBreakdown;
use super::router::{Coordinator, CoordinatorConfig, SubmitError};
use crate::bench_support::record::Recorder;
use crate::obs::{trace as obs_trace, Category as ObsCategory, Phase as ObsPhase, SpanEvent};
use crate::util::timer::BenchResult;
use crate::util::XorShift256;

/// Stream-id namespaces of the scenario's seeded draws (operands and
/// arrival jitter; disjoint from the loadgen and governor namespaces).
const SCEN_OPERAND_STREAM: u64 = 0x5343_0000_0001_0000;
const SCEN_ARRIVAL_STREAM: u64 = 0x5343_0000_0000_0001;

/// Operand regime of one scenario phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Narrow operands (half the serving width): approximate products are
    /// near-exact, QoR sits far above any floor — the regime that lets
    /// the governor decay to cheap rungs.
    Clean,
    /// Full-width operands: the cheap rungs' error is fully exposed and a
    /// QoR floor forces upgrades.
    Noisy,
}

impl Regime {
    /// Parse a regime name (`clean` / `noisy`).
    pub fn parse(s: &str) -> Result<Regime, String> {
        match s {
            "clean" => Ok(Regime::Clean),
            "noisy" => Ok(Regime::Noisy),
            other => Err(format!("unknown regime '{other}' (expected 'clean' or 'noisy')")),
        }
    }

    /// Lower-case label (CLI round-trip of [`Regime::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            Regime::Clean => "clean",
            Regime::Noisy => "noisy",
        }
    }
}

/// One scenario phase: `requests` arrivals offered at `rate`/s under one
/// operand regime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Phase {
    /// Operand regime of every request in the phase.
    pub regime: Regime,
    /// Arrivals in the phase.
    pub requests: u64,
    /// Offered rate (requests/second, > 0).
    pub rate: u64,
}

/// Parse a scenario spec: comma-separated `regime:requests:rate` phases,
/// e.g. `clean:2000:20000,noisy:2000:20000`. Every malformed field is a
/// clean `Err` (the CLI error paths `tests/governor_e2e.rs` pins).
pub fn parse_phases(s: &str) -> Result<Vec<Phase>, String> {
    let mut phases = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            return Err(format!("--phases '{s}': empty phase entry"));
        }
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() != 3 {
            return Err(format!(
                "--phases: '{part}' is not 'regime:requests:rate' (e.g. 'noisy:2000:20000')"
            ));
        }
        let regime = Regime::parse(fields[0])?;
        let requests: u64 = fields[1]
            .parse()
            .map_err(|_| format!("--phases: '{}' is not a request count", fields[1]))?;
        if requests == 0 {
            return Err(format!("--phases: '{part}' has a zero request count"));
        }
        let rate: u64 = fields[2]
            .parse()
            .map_err(|_| format!("--phases: '{}' is not a rate (requests/s)", fields[2]))?;
        if rate == 0 {
            return Err(format!("--phases: '{part}' has a zero rate"));
        }
        phases.push(Phase { regime, requests, rate });
    }
    if phases.is_empty() {
        return Err("--phases: at least one phase is required".to_string());
    }
    Ok(phases)
}

/// A governed scenario: the workload, the app scoring it, and the
/// governor/serving knobs.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    /// Application whose QoR metric scores the stream.
    pub app: App,
    /// Operand width of the served multiplications.
    pub width: u32,
    /// The phase schedule.
    pub phases: Vec<Phase>,
    /// Operand lanes per request.
    pub req_len: usize,
    /// Master seed of the operand / jitter / sampling streams.
    pub seed: u64,
    /// Governor policy knobs (window, dwell, floor, ...).
    pub governor: GovernorConfig,
    /// Rung the ladder starts serving at.
    pub start_rung: usize,
    /// Per-request deadline for admission control (None = nothing sheds;
    /// the deterministic-trace configuration).
    pub deadline: Option<Duration>,
}

impl ScenarioConfig {
    /// Total arrivals across all phases.
    pub fn total_requests(&self) -> u64 {
        self.phases.iter().map(|p| p.requests).sum()
    }

    /// Regime of global request `k` (pure function of the phase table).
    pub fn regime_of(&self, k: u64) -> Regime {
        let mut off = 0u64;
        for p in &self.phases {
            if k < off + p.requests {
                return p.regime;
            }
            off += p.requests;
        }
        self.phases.last().expect("phases non-empty").regime
    }
}

/// The fixed operand streams of a scenario: request `k` always carries
/// these lanes, independent of pacing, sharding, completion order or the
/// rung it is served at. Clean phases draw `width/2`-bit operands, noisy
/// phases full-width ones.
pub fn scenario_operands(cfg: &ScenarioConfig, k: u64) -> (Vec<i64>, Vec<i64>) {
    let bits = match cfg.regime_of(k) {
        Regime::Clean => (cfg.width / 2).max(2),
        Regime::Noisy => cfg.width,
    };
    let mut rng = XorShift256::new(cfg.seed).split(SCEN_OPERAND_STREAM ^ k);
    let a = (0..cfg.req_len).map(|_| rng.bits(bits) as i64).collect();
    let b = (0..cfg.req_len).map(|_| rng.bits(bits) as i64).collect();
    (a, b)
}

/// Seeded arrival offsets (ns since phase start) of one phase: request
/// `j` of the phase sits in slot `j · spacing` with sub-slot jitter —
/// same construction as `loadgen::schedule`, with the count given
/// directly instead of derived from a duration.
pub fn phase_schedule(phase_idx: usize, phase: &Phase, seed: u64) -> Vec<u64> {
    let spacing = (1_000_000_000 / phase.rate).max(1);
    let mut rng =
        XorShift256::new(seed).split(SCEN_ARRIVAL_STREAM ^ ((phase_idx as u64) << 32) ^ phase.rate);
    (0..phase.requests).map(|j| j * spacing + rng.below(spacing)).collect()
}

/// Submit-side tallies of one phase (wall-clock-free apart from rates).
#[derive(Clone, Debug)]
pub struct PhaseReport {
    /// The phase as configured.
    pub phase: Phase,
    /// Requests past admission control and the bounded queues.
    pub admitted: u64,
    /// Requests shed by deadline admission control.
    pub shed: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Rung in effect when the phase started / ended.
    pub start_rung: usize,
    pub end_rung: usize,
}

/// Everything one governed scenario run produced.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// The replayable governor record (windows + transitions).
    pub trace: GovernorTrace,
    /// Per-phase submit tallies.
    pub phases: Vec<PhaseReport>,
    /// Registry names of the ladder rungs (cheapest first).
    pub rung_names: Vec<String>,
    /// Total arrivals offered.
    pub requests: u64,
    /// Requests fully completed (all spans replied).
    pub completed: u64,
    /// Operand lanes across completed requests.
    pub elements: u64,
    /// Wall clock of the whole scenario (ns).
    pub wall_ns: u64,
    /// Order-independent digest of every completed response — with no
    /// shedding, a pure function of (seed, phases, ladder, policy): the
    /// end-to-end bit-identity handle of a governed run.
    pub checksum: u64,
    /// p50 / p99 span latency at scenario end (ns; wall-clock).
    pub p50_ns: u64,
    pub p99_ns: u64,
    /// Where the latency went: per-phase p50/p99 from the coordinator's
    /// bucketed `rapid_phase_ns` histograms (merged across shards).
    pub phase_breakdown: PhaseBreakdown,
    /// Trace spans captured during the run (empty unless the recorder was
    /// enabled — `serve-bench --governor --trace`). Under the logical
    /// clock with no deadline, a pure function of seed/phases/policy.
    pub spans: Vec<SpanEvent>,
}

impl ScenarioReport {
    /// Rung that served request `k` (from the recorded window stream).
    pub fn rung_of_request(&self, k: u64, window: u64) -> Option<usize> {
        let w = (k / window.max(1)) as usize;
        self.trace.windows.get(w).map(|o| o.rung)
    }
}

/// Drive one governed scenario against a fresh coordinator.
///
/// The submitting thread walks the phase schedules (sleep + spin pacing),
/// stamps each request with the governor's current rung (inside
/// `Coordinator::try_call_async_with_deadline` via the rung register),
/// shadow-samples the seeded stride, and closes a decision window every
/// `governor.window` *offered* requests: fold the window's samples into
/// the app QoR, feed the observation to the [`Governor`], and actuate any
/// transition with [`Coordinator::set_rung`] before the next request is
/// submitted — so a window's requests are all served at one rung and the
/// switch trace is a pure function of the seed and policy. A collector
/// thread reassembles replies into the order-independent checksum
/// (`loadgen` pattern).
pub fn run_scenario(
    ladder: &Ladder,
    coord_cfg: &CoordinatorConfig,
    cfg: &ScenarioConfig,
) -> ScenarioReport {
    assert_eq!(ladder.width, cfg.width, "ladder and scenario widths must agree");
    // sampled once up front: a recorder enabled mid-run (another thread)
    // must not leak a partial capture into this report
    let tracing = obs_trace::enabled();
    let gcfg = cfg.governor;
    let window = gcfg.window.max(1);
    let coord = Coordinator::start(ladder.factory(), coord_cfg.clone());
    let mut governor = Governor::new(gcfg, ladder.len(), cfg.start_rung);
    coord.set_rung(governor.rung() as u32);

    // collector: reassemble each admitted request's spans, fold digests
    type Pending = (u64, usize, std::sync::mpsc::Receiver<super::router::Response>);
    let (done_tx, done_rx) = channel::<Pending>();
    let collector = std::thread::spawn(move || {
        let mut checksum = 0u64;
        let mut completed = 0u64;
        let mut elements = 0u64;
        while let Ok((k, n, rx)) = done_rx.recv() {
            let mut vals = vec![0i64; n];
            let mut filled = 0usize;
            while filled < n {
                match rx.recv() {
                    Ok(resp) => {
                        let end = resp.offset + resp.values.len();
                        vals[resp.offset..end].copy_from_slice(&resp.values);
                        filled += resp.values.len();
                    }
                    Err(_) => break,
                }
            }
            if filled == n {
                checksum ^= request_digest(k, &vals);
                completed += 1;
                elements += n as u64;
            }
        }
        (checksum, completed, elements)
    });

    let mut trace = GovernorTrace::default();
    let mut acc = WindowAccumulator::new();
    let mut phase_reports: Vec<PhaseReport> = Vec::new();
    let mut window_shed = 0u64;
    let t0 = Instant::now();
    let mut k = 0u64; // global request index

    // close the decision window `w` and actuate any switch
    let mut close_window = |w: u64,
                            governor: &mut Governor,
                            acc: &mut WindowAccumulator,
                            window_shed: &mut u64,
                            trace: &mut GovernorTrace| {
        let rung = governor.rung();
        let (qor, qor_down) = acc.close(cfg.app, cfg.width, rung);
        let obs = WindowObs {
            window: w,
            rung,
            qor,
            qor_down,
            shed: *window_shed,
            p99_ns: coord.metrics.p99_ns(),
        };
        *window_shed = 0;
        coord.metrics.record_governor_window(qor);
        // identity-pure span (id = window, rung = the rung that served
        // it, val = the QoR observation): deterministic under the
        // logical clock, same contract as the replayable trace
        obs_trace::record_val(ObsCategory::Governor, ObsPhase::Window, w, 0, rung as u32, qor);
        if let Some(t) = governor.observe(&obs) {
            coord.set_rung(t.to as u32);
            coord.metrics.record_governor_switch();
            obs_trace::record_instant(ObsCategory::Governor, ObsPhase::Switch, w, 0, t.to as u32);
            trace.transitions.push(t);
        }
        trace.windows.push(obs);
    };

    for (pi, phase) in cfg.phases.iter().enumerate() {
        let arrivals = phase_schedule(pi, phase, cfg.seed);
        let mut rep = PhaseReport {
            phase: *phase,
            admitted: 0,
            shed: 0,
            rejected: 0,
            start_rung: governor.rung(),
            end_rung: governor.rung(),
        };
        let p0 = Instant::now();
        for &at_ns in &arrivals {
            // window boundary: decide *before* the first request of the
            // new window is stamped
            if k > 0 && k % window == 0 {
                close_window(k / window - 1, &mut governor, &mut acc, &mut window_shed, &mut trace);
            }
            // pace: coarse sleep, then spin the last stretch
            let target = p0 + Duration::from_nanos(at_ns);
            loop {
                let now = Instant::now();
                if now >= target {
                    break;
                }
                let left = target - now;
                if left > Duration::from_micros(120) {
                    std::thread::sleep(left - Duration::from_micros(100));
                } else {
                    std::hint::spin_loop();
                }
            }
            let (a, b) = scenario_operands(cfg, k);
            // shadow-sample the seeded stride (offered requests, so the
            // QoR signal is independent of admission outcomes)
            if is_sampled(cfg.seed, gcfg.sample_stride, k / window, k) {
                acc.sample(ladder, governor.rung(), &a, &b, gcfg.sample_lanes);
            }
            let n = a.len();
            match coord.try_call_async_with_deadline(a, b, cfg.deadline) {
                Ok(rx) => {
                    rep.admitted += 1;
                    done_tx.send((k, n, rx)).expect("collector alive");
                }
                Err(SubmitError::Shed) => {
                    rep.shed += 1;
                    window_shed += 1;
                }
                Err(SubmitError::Full) => rep.rejected += 1,
            }
            k += 1;
        }
        rep.end_rung = governor.rung();
        phase_reports.push(rep);
    }
    // close the trailing (possibly partial) window
    if k > 0 {
        close_window((k - 1) / window, &mut governor, &mut acc, &mut window_shed, &mut trace);
    }

    drop(done_tx);
    let (checksum, completed, elements) = collector.join().expect("collector");
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let mut report = ScenarioReport {
        trace,
        phases: phase_reports,
        rung_names: ladder.names.clone(),
        requests: k,
        completed,
        elements,
        wall_ns,
        checksum,
        p50_ns: coord.metrics.p50_ns(),
        p99_ns: coord.metrics.p99_ns(),
        phase_breakdown: coord.metrics.phase_breakdown(),
        spans: Vec::new(),
    };
    // drop first: the coordinator joins its threads, so every in-flight
    // span has landed in a ring before the drain
    drop(coord);
    if tracing {
        report.spans = obs_trace::take().events;
    }
    report
}

/// Pour a scenario report into a [`Recorder`] for `BENCH_governor.json`:
/// one throughput row per phase plus scenario-level switch/QoR rows
/// (`items_per_iter` carries the deterministic counters so the JSON is
/// self-describing).
pub fn to_recorder(rep: &ScenarioReport, window: u64) -> Recorder {
    let mut rec = Recorder::new("governor");
    let one = |ns: f64| BenchResult {
        name: String::new(),
        median_ns: ns,
        mean_ns: ns,
        min_ns: ns,
        max_ns: ns,
        samples: 1,
        iters_per_sample: 1,
    };
    for (i, p) in rep.phases.iter().enumerate() {
        let name = format!(
            "phase{}_{}_{}rps_rung{}to{}",
            i,
            p.phase.regime.label(),
            p.phase.rate,
            p.start_rung,
            p.end_rung
        );
        rec.add(&name, &one(rep.wall_ns as f64 / rep.phases.len() as f64), p.admitted as f64);
    }
    rec.add("switches_total", &one(rep.wall_ns as f64), rep.trace.transitions.len() as f64);
    rec.add(
        "windows_total",
        &one(rep.wall_ns as f64),
        (rep.requests.div_ceil(window.max(1))) as f64,
    );
    rec.add("p99_latency", &one(rep.p99_ns as f64), 1.0);
    rec.add("queue_p99", &one(rep.phase_breakdown.queue_p99_ns as f64), 1.0);
    rec.add("batch_form_p99", &one(rep.phase_breakdown.batch_form_p99_ns as f64), 1.0);
    rec.add("execute_p99", &one(rep.phase_breakdown.execute_p99_ns as f64), 1.0);
    rec
}

/// Human-readable scenario summary: phase table + switch trace.
pub fn format_report(rep: &ScenarioReport) -> String {
    let mut out = String::new();
    out.push_str(&format!("ladder: {}\n", rep.rung_names.join(" -> ")));
    for (i, p) in rep.phases.iter().enumerate() {
        out.push_str(&format!(
            "phase {} {:<5} {:>7} req @ {:>8} req/s | admitted {:>7} shed {:>6} rejected {:>6} | rung {} -> {}\n",
            i,
            p.phase.regime.label(),
            p.phase.requests,
            p.phase.rate,
            p.admitted,
            p.shed,
            p.rejected,
            p.start_rung,
            p.end_rung,
        ));
    }
    out.push_str(&format!(
        "completed {}/{} | {} switches over {} windows | p50 {:.1}µs p99 {:.1}µs | checksum {:016x}\n",
        rep.completed,
        rep.requests,
        rep.trace.transitions.len(),
        rep.trace.windows.len(),
        rep.p50_ns as f64 / 1e3,
        rep.p99_ns as f64 / 1e3,
        rep.checksum,
    ));
    out.push_str(&format!(
        "phase p99: queue {:.1}µs batch_form {:.1}µs execute {:.1}µs\n",
        rep.phase_breakdown.queue_p99_ns as f64 / 1e3,
        rep.phase_breakdown.batch_form_p99_ns as f64 / 1e3,
        rep.phase_breakdown.execute_p99_ns as f64 / 1e3,
    ));
    if rep.trace.transitions.is_empty() {
        out.push_str("switch trace: (none)\n");
    } else {
        out.push_str("switch trace:\n");
        for line in rep.trace.switch_trace().lines() {
            out.push_str(&format!("  {line}\n"));
        }
    }
    out
}

/// `rapid serve-bench --governor` — parse/validate/run split so every
/// malformed input is a clean `Err` (satellite error-path tests), and the
/// process-exit shell lives in one place (`loadgen::cli`).
pub mod cli {
    use super::*;
    use crate::explore::evaluate::EvalOpts;
    use crate::util::cli::Args;

    /// Everything a governed serve-bench run needs, fully validated.
    pub struct ScenarioSetup {
        /// Scenario + governor knobs.
        pub cfg: ScenarioConfig,
        /// Ladder rung names (cheapest first), already registry-checked.
        pub ladder_names: Vec<String>,
        /// Reorder/filter the names through the exact Pareto frontier.
        pub use_pareto: bool,
        /// Pipeline stages of the Pareto evaluation.
        pub stages: usize,
        /// Fidelity of the Pareto evaluation.
        pub mc_samples: u64,
        pub power_vectors: usize,
        /// Serving shell shape.
        pub coord: CoordinatorConfig,
        /// Output JSON path.
        pub out: String,
        /// Chrome-trace output path (`--trace FILE`); None = no tracing.
        pub trace: Option<String>,
        /// Recorder clock (`--clock monotonic|logical`).
        pub clock: obs_trace::Clock,
    }

    /// Option keys of the governed mode (superset of the plain
    /// serve-bench keys so one argv parses either way).
    pub const VALUE_KEYS: &[&str] = &[
        "backend", "unit", "op", "width", "rates", "duration-ms", "req-len", "seed",
        "batch", "workers", "shards", "queue-depth", "max-wait-us", "deadline-us", "out",
        "app", "ladder", "phases", "qor-floor", "headroom", "window", "dwell",
        "sample-stride", "sample-lanes", "start-rung", "p99-budget-us", "stages",
        "samples", "vectors", "trace", "clock",
    ];

    /// Validate a governed serve-bench argv into a [`ScenarioSetup`].
    /// Pure (no I/O, nothing served): the function the error-path tests
    /// drive with malformed inputs.
    pub fn parse(argv: Vec<String>) -> Result<ScenarioSetup, String> {
        let args = Args::parse(argv, VALUE_KEYS);
        let backend = args.get_or("backend", "functional");
        if backend != "functional" {
            return Err(format!(
                "--governor serves the in-process functional ladder (got backend '{backend}'); \
                 the PJRT path serves one fixed artifact"
            ));
        }
        if args.get_or("op", "mul") != "mul" {
            return Err("--governor ladders are multiplier ladders (--op mul)".to_string());
        }
        let app = App::parse(args.get_or("app", "jpeg"))?;
        let width = {
            let w = args.try_u64("width", 16)? as u32;
            if !(2..=32).contains(&w) {
                return Err(format!("--width: {w} is outside the supported 2..=32 range"));
            }
            w
        };
        let phases = parse_phases(args.get_or(
            "phases",
            "clean:2000:20000,noisy:2000:20000,clean:2000:20000",
        ))?;
        let ladder_spec = args.get_or("ladder", "rapid3,rapid10,exact");
        let ladder_names: Vec<String> =
            ladder_spec.split(',').map(|s| s.trim().to_string()).collect();
        // registry-check now so a typo fails before anything is served
        Ladder::from_names(&ladder_names, width)?;

        let floor = args.try_f64("qor-floor", app.default_floor())?;
        if !floor.is_finite() {
            return Err(format!("--qor-floor: {floor} must be finite"));
        }
        let headroom = args.try_f64("headroom", app.default_headroom())?;
        if !headroom.is_finite() || headroom < 0.0 {
            return Err(format!("--headroom: {headroom} must be finite and non-negative"));
        }
        let seed = args.try_u64("seed", 42)?;
        let deadline_us = args.try_u64("deadline-us", 0)?;
        let governor = GovernorConfig {
            floor,
            headroom,
            window: args.try_u64("window", 256)?.max(1),
            dwell: args.try_u64("dwell", 3)?.max(1),
            sample_stride: args.try_u64("sample-stride", 8)?.max(1),
            sample_lanes: args.try_usize("sample-lanes", 32)?.max(1),
            seed,
            p99_budget_ns: args.try_u64("p99-budget-us", 0)? * 1000,
        };
        let cfg = ScenarioConfig {
            app,
            width,
            phases,
            req_len: args.try_usize("req-len", 256)?.max(1),
            seed,
            governor,
            start_rung: args.try_usize("start-rung", 0)?,
            deadline: (deadline_us > 0).then(|| Duration::from_micros(deadline_us)),
        };
        Ok(ScenarioSetup {
            cfg,
            ladder_names,
            use_pareto: args.flag("pareto"),
            stages: args.try_usize("stages", 1)?.max(1),
            mc_samples: args.try_u64("samples", 50_000)?.max(1),
            power_vectors: args.try_usize("vectors", 24)?.max(1),
            coord: CoordinatorConfig {
                batch_capacity: args.try_usize("batch", 4096)?.max(1),
                max_wait: Duration::from_micros(args.try_u64("max-wait-us", 200)?),
                workers: args.try_usize("workers", 4)?.max(1),
                queue_depth: args.try_usize("queue-depth", 256)?.max(1),
                shards: args.try_usize("shards", 4)?.max(1),
            },
            out: args.get_or("out", "BENCH_governor.json").to_string(),
            trace: args.get("trace").map(String::from),
            clock: match args.get("clock") {
                None => obs_trace::Clock::Monotonic,
                Some(c) => obs_trace::Clock::parse(c)
                    .ok_or_else(|| format!("--clock: '{c}' is not 'monotonic' or 'logical'"))?,
            },
        })
    }

    /// Build the ladder a setup asks for (explicit order, or Pareto-
    /// reordered cheapest→most-accurate). `--pareto` needs `'static`
    /// registry names, so the owned names are matched back through the
    /// registry table.
    pub fn build_ladder(setup: &ScenarioSetup) -> Result<Ladder, String> {
        if !setup.use_pareto {
            return Ladder::from_names(&setup.ladder_names, setup.cfg.width);
        }
        let mut stat: Vec<&'static str> = Vec::with_capacity(setup.ladder_names.len());
        for n in &setup.ladder_names {
            stat.push(
                crate::arith::registry::static_mul_name(n)
                    .ok_or_else(|| format!("--pareto: '{n}' is not a registry multiplier name"))?,
            );
        }
        let opts = EvalOpts {
            mc_samples: setup.mc_samples,
            power_vectors: setup.power_vectors,
            ..Default::default()
        };
        Ladder::pareto(&stat, setup.cfg.width, setup.stages, &opts)
    }

    /// Run a governed serve-bench end to end. `Err` carries the
    /// user-facing message (the caller prints it and sets the exit code).
    pub fn run(argv: Vec<String>) -> Result<(), String> {
        let setup = parse(argv)?;
        let ladder = build_ladder(&setup)?;
        let g = &setup.cfg.governor;
        println!(
            "serve-bench --governor: app {} ({}, floor {} headroom {}), ladder [{}], \
             window {} dwell {} stride {}, shards {}, workers {}, start rung {}",
            match setup.cfg.app {
                App::Jpeg => "jpeg",
                App::Ecg => "ecg",
                App::Harris => "harris",
            },
            setup.cfg.app.qor_name(),
            g.floor,
            g.headroom,
            ladder.names.join(","),
            g.window,
            g.dwell,
            g.sample_stride,
            setup.coord.shards,
            setup.coord.workers,
            setup.cfg.start_rung,
        );
        if setup.trace.is_some() {
            obs_trace::enable(setup.clock);
        }
        let rep = run_scenario(&ladder, &setup.coord, &setup.cfg);
        if let Some(path) = &setup.trace {
            obs_trace::disable();
            std::fs::write(path, crate::obs::chrome::to_chrome_json(&rep.spans))
                .map_err(|e| format!("could not write {path}: {e}"))?;
            println!("trace -> {path} (inspect with `rapid trace-report --in {path}`)");
        }
        print!("{}", format_report(&rep));
        to_recorder(&rep, g.window)
            .write(&setup.out)
            .map_err(|e| format!("could not write {}: {e}", setup.out))?;
        println!("recorded -> {} (the EXPERIMENTS.md §Governor trajectory)", setup.out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> ScenarioConfig {
        ScenarioConfig {
            app: App::Jpeg,
            width: 16,
            phases: vec![
                Phase { regime: Regime::Clean, requests: 100, rate: 50_000 },
                Phase { regime: Regime::Noisy, requests: 100, rate: 50_000 },
            ],
            req_len: 32,
            seed: 7,
            governor: GovernorConfig {
                window: 50,
                dwell: 1,
                sample_stride: 4,
                sample_lanes: 8,
                seed: 7,
                ..Default::default()
            },
            start_rung: 0,
            deadline: None,
        }
    }

    #[test]
    fn phase_spec_parses_and_rejects() {
        let p = parse_phases("clean:2000:20000,noisy:1000:5000").unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0], Phase { regime: Regime::Clean, requests: 2000, rate: 20000 });
        assert_eq!(p[1].regime, Regime::Noisy);
        for bad in [
            "", "clean", "clean:10", "clean:10:0", "clean:0:100", "murky:10:100",
            "clean:ten:100", "clean:10:-5", "clean:10:100,,noisy:5:5",
        ] {
            assert!(parse_phases(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn operands_follow_the_phase_regimes() {
        let cfg = base_cfg();
        // clean phase: width/2-bit operands
        let (a, _) = scenario_operands(&cfg, 0);
        assert_eq!(a.len(), 32);
        assert!(a.iter().all(|&x| (0..256).contains(&x)), "clean = 8-bit at width 16");
        // noisy phase: full-width operands (some above the clean cap)
        let (a, _) = scenario_operands(&cfg, 150);
        assert!(a.iter().all(|&x| (0..65536).contains(&x)));
        assert!(a.iter().any(|&x| x >= 256), "noisy draws beyond the clean range");
        // pure: same k, same lanes
        assert_eq!(scenario_operands(&cfg, 150), scenario_operands(&cfg, 150));
        // past-the-end indexing clamps to the last phase's regime
        assert_eq!(cfg.regime_of(10_000), Regime::Noisy);
    }

    #[test]
    fn phase_schedule_is_seeded_and_paced() {
        let p = Phase { regime: Regime::Clean, requests: 100, rate: 1_000_000 };
        let s1 = phase_schedule(0, &p, 3);
        assert_eq!(s1, phase_schedule(0, &p, 3));
        assert_eq!(s1.len(), 100);
        for w in s1.windows(2) {
            assert!(w[0] <= w[1], "sorted");
        }
        assert!(*s1.last().unwrap() < 100 * 1000, "inside the phase");
        assert_ne!(s1, phase_schedule(1, &p, 3), "phase index varies jitter");
    }

    #[test]
    fn cli_parse_rejects_malformed_inputs() {
        let sv = |s: &[&str]| s.iter().map(|x| x.to_string()).collect::<Vec<_>>();
        assert!(cli::parse(sv(&[])).is_ok(), "defaults parse");
        for bad in [
            vec!["--app", "video"],
            vec!["--ladder", "rapid3,nosuchunit"],
            vec!["--ladder", "rapid3,,exact"],
            vec!["--phases", "clean:100:0"],
            vec!["--phases", "noisy:-5:100"],
            vec!["--window", "-3"],
            vec!["--qor-floor", "lots"],
            vec!["--backend", "pjrt"],
            vec!["--op", "div"],
            vec!["--width", "64"],
            vec!["--clock", "wall"],
        ] {
            let owned = sv(&bad);
            assert!(cli::parse(owned.clone()).is_err(), "{owned:?} must be rejected");
        }
    }

    #[test]
    fn short_scenario_upgrades_under_noise_and_is_replayable() {
        let ladder = Ladder::from_names(&["rapid3", "exact"], 16).unwrap();
        let coord = CoordinatorConfig {
            batch_capacity: 64,
            max_wait: Duration::from_micros(50),
            workers: 2,
            queue_depth: 1024,
            shards: 1,
        };
        let cfg = base_cfg();
        let rep = run_scenario(&ladder, &coord, &cfg);
        assert_eq!(rep.requests, 200);
        assert_eq!(rep.completed, 200, "no deadline → nothing sheds");
        assert_eq!(rep.trace.windows.len(), 4, "200 requests / window 50");
        // clean phase holds the cheap rung, noisy phase forces the exact one
        assert_eq!(rep.phases[0].start_rung, 0);
        assert_eq!(rep.phases[1].end_rung, 1, "noisy regime upgraded");
        assert!(rep
            .trace
            .transitions
            .iter()
            .any(|t| t.reason == crate::coordinator::governor::SwitchReason::QorFloor));
        // the recorded trace replays exactly
        let replayed = Governor::replay(cfg.governor, ladder.len(), cfg.start_rung, &rep.trace.windows);
        assert_eq!(replayed, rep.trace.transitions);
    }

    #[test]
    fn recorder_carries_phases_and_switches() {
        let rep = ScenarioReport {
            trace: GovernorTrace::default(),
            phases: vec![PhaseReport {
                phase: Phase { regime: Regime::Noisy, requests: 100, rate: 5000 },
                admitted: 100,
                shed: 0,
                rejected: 0,
                start_rung: 0,
                end_rung: 1,
            }],
            rung_names: vec!["rapid3".into(), "exact".into()],
            requests: 100,
            completed: 100,
            elements: 3200,
            wall_ns: 1_000_000,
            checksum: 0xfeed,
            p50_ns: 1000,
            p99_ns: 2000,
            phase_breakdown: PhaseBreakdown { queue_p99_ns: 8192, ..PhaseBreakdown::default() },
            spans: Vec::new(),
        };
        let j = to_recorder(&rep, 50).to_json();
        assert!(j.contains("\"bench\": \"governor\""), "{j}");
        assert!(j.contains("phase0_noisy_5000rps_rung0to1"), "{j}");
        assert!(j.contains("switches_total"), "{j}");
        assert!(j.contains("queue_p99"), "{j}");
        assert!(j.contains("execute_p99"), "{j}");
        let text = format_report(&rep);
        assert!(text.contains("rapid3 -> exact"), "{text}");
        assert!(text.contains("switch trace: (none)"), "{text}");
        assert!(text.contains("phase p99: queue"), "{text}");
    }
}
