//! Request router + worker pool: the sharded ingress of the serving shell.
//!
//! Requests (operand vectors) enter through bounded queues (backpressure:
//! `submit` blocks, `try_*` rejects when full), one of N independent
//! *lanes* packs them through its own `DynamicBatcher`, full batches are
//! dispatched to the lane's worker pool over a second bounded channel,
//! workers execute a pluggable `Executor` (the PJRT artifact in
//! production; an in-process functional model in tests — the mock the
//! integration tests inject), and results are scattered back to
//! per-request reply channels.
//!
//! ## Sharding
//!
//! With `shards == 1` this is the classic single-leader loop: one ingress
//! queue, one batching thread, `workers` executor threads — the oracle
//! the sharded path is pinned bit-identical against. With `shards == N`
//! the coordinator runs N fully independent lanes (own bounded ingress
//! queue, own batcher thread, own worker pool), and the *submitting*
//! thread routes each request round-robin, so batch formation and
//! dispatch scale with cores instead of serializing on one leader. A
//! request is routed whole — its spans never cross lanes — and every
//! lane serves the identical unit on independent operand lanes with inert
//! zero padding, so replies are bit-identical to the single-leader path
//! regardless of shard count or routing order (pinned by
//! `tests/coordinator_e2e.rs`).
//!
//! ## Deadlines
//!
//! A request may carry a deadline. Admission control runs *at enqueue*:
//! the submitting thread estimates the wait as
//! `max_wait + (queue_depth + 1) · ewma_batch_service` for its lane and
//! sheds the request — counted in [`Metrics::shed`], never enqueued,
//! never executed — when the estimate exceeds the deadline. Once
//! admitted, a request always executes (its measured latency, not a
//! mid-queue drop, reflects any overload); the bounded queues still
//! provide hard backpressure independently of deadlines.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, DynamicBatcher};
use super::metrics::{Metrics, ServePhase};
use crate::obs::trace::{self, Category, Phase};

/// Work executed per batch. Constructed *inside* each worker thread by an
/// [`ExecutorFactory`] — PJRT handles are not `Send`, so every worker owns
/// a thread-local client/executable.
pub trait Executor {
    /// Elementwise op over the packed batch.
    fn execute(&mut self, a: &[i64], b: &[i64]) -> Vec<i64>;

    /// Rung-aware variant the workers actually call: `rung` is the
    /// accuracy-ladder index the batch was stamped with (batches never mix
    /// rungs — see [`super::batcher::DynamicBatcher::offer_into`]).
    /// Single-unit executors ignore it; the governor's ladder executor
    /// ([`LadderMulFactory`]) dispatches on it. The default forwards to
    /// [`Self::execute`], so pre-governor executors (PJRT, closures) are
    /// untouched.
    fn execute_rung(&mut self, _rung: u32, a: &[i64], b: &[i64]) -> Vec<i64> {
        self.execute(a, b)
    }
}

impl<F> Executor for F
where
    F: FnMut(&[i64], &[i64]) -> Vec<i64>,
{
    fn execute(&mut self, a: &[i64], b: &[i64]) -> Vec<i64> {
        self(a, b)
    }
}

/// Creates one executor per worker thread.
pub trait ExecutorFactory: Send + Sync + 'static {
    /// Build a fresh executor (called once inside each worker thread).
    fn make(&self) -> Box<dyn Executor>;
}

/// Factory from a cloneable pure function (tests / functional models).
pub struct FnFactory<F>(pub F);

impl<F> ExecutorFactory for FnFactory<F>
where
    F: Fn(&[i64], &[i64]) -> Vec<i64> + Send + Sync + Clone + 'static,
{
    fn make(&self) -> Box<dyn Executor> {
        let f = self.0.clone();
        Box::new(move |a: &[i64], b: &[i64]| f(a, b))
    }
}

/// In-process functional serving over a shared multiplier unit: each worker
/// executes a served batch through [`crate::arith::ApproxMul::mul_batch`] —
/// one call per [`UNIT_SHARD_LANES`]-lane shard, sharded across cores by
/// the deterministic parallel engine when the batch exceeds one shard
/// (lanes are independent, so replies are bit-identical at every thread
/// count; batches at or below one shard run inline on the worker thread
/// with no spawn). The per-worker executor keeps its operand/result
/// scratch buffers across batches; the fan-out path allocates its
/// bookkeeping per batch, a cost amortised across the shard's thousands
/// of lanes. Deployments that prefer worker-pool-only parallelism (many
/// concurrent batches rather than large ones) set `RAPID_THREADS=1`,
/// which also makes the fan-out path spawn-free.
///
/// Wire format: the `Executor` API carries i64 lanes; operands and results
/// are reinterpreted as u64 bit patterns (`as u64` / `as i64`). For a
/// 32-bit unit a full-scale product sets the i64 sign bit — callers must
/// convert replies back with `as u64`, exactly like the PJRT path's i64
/// buffers.
pub struct BatchMulFactory {
    /// The multiplier every worker's executor shares.
    pub unit: Arc<dyn crate::arith::ApproxMul>,
}

impl ExecutorFactory for BatchMulFactory {
    fn make(&self) -> Box<dyn Executor> {
        Box::new(BatchUnitExecutor { op: BatchOp::Mul(self.unit.clone()), a: Vec::new(), b: Vec::new(), out: Vec::new() })
    }
}

/// Divider twin of [`BatchMulFactory`]: one
/// [`crate::arith::ApproxDiv::div_batch`] per served batch.
pub struct BatchDivFactory {
    /// The divider every worker's executor shares.
    pub unit: Arc<dyn crate::arith::ApproxDiv>,
}

impl ExecutorFactory for BatchDivFactory {
    fn make(&self) -> Box<dyn Executor> {
        Box::new(BatchUnitExecutor { op: BatchOp::Div(self.unit.clone()), a: Vec::new(), b: Vec::new(), out: Vec::new() })
    }
}

enum BatchOp {
    Mul(Arc<dyn crate::arith::ApproxMul>),
    Div(Arc<dyn crate::arith::ApproxDiv>),
}

/// Lanes per shard when a served batch fans out over
/// [`crate::util::par`]. Deliberately coarse — the engine spawns scoped
/// threads per fan-out, so a shard must carry enough `mul_batch` work to
/// clearly amortise a spawn/join: at the default 8 192-lane batch
/// capacity this yields two shards, and batches at or below one shard
/// stay on the worker thread (the engine runs single-chunk ranges
/// inline, spawn-free).
const UNIT_SHARD_LANES: usize = 4096;

struct BatchUnitExecutor {
    op: BatchOp,
    a: Vec<u64>,
    b: Vec<u64>,
    out: Vec<u64>,
}

impl Executor for BatchUnitExecutor {
    fn execute(&mut self, a: &[i64], b: &[i64]) -> Vec<i64> {
        self.a.clear();
        self.a.extend(a.iter().map(|&x| x as u64));
        self.b.clear();
        self.b.extend(b.iter().map(|&x| x as u64));
        self.out.clear();
        self.out.resize(a.len(), 0);
        let (ua, ub) = (&self.a, &self.b);
        match &self.op {
            BatchOp::Mul(u) => {
                crate::util::par::par_chunks_mut(&mut self.out, UNIT_SHARD_LANES, |_c, off, o| {
                    u.mul_batch(&ua[off..off + o.len()], &ub[off..off + o.len()], o);
                });
            }
            BatchOp::Div(u) => {
                crate::util::par::par_chunks_mut(&mut self.out, UNIT_SHARD_LANES, |_c, off, o| {
                    u.div_batch(&ua[off..off + o.len()], &ub[off..off + o.len()], o);
                });
            }
        }
        self.out.iter().map(|&x| x as i64).collect()
    }
}

/// Accuracy-ladder serving: one executor holding every rung of a
/// multiplier ladder (cheapest → most accurate, the order
/// [`crate::coordinator::governor::Ladder`] produces). Each batch executes
/// through the unit at the batch's stamped rung — the same sharded
/// `mul_batch` fan-out as [`BatchMulFactory`], so a one-rung ladder is
/// bit-identical to serving that unit directly. Out-of-range rungs clamp
/// to the most accurate unit (fail-safe: QoR can only improve).
pub struct LadderMulFactory {
    /// The ladder every worker's executor shares, cheapest first.
    pub units: Vec<Arc<dyn crate::arith::ApproxMul>>,
}

impl ExecutorFactory for LadderMulFactory {
    fn make(&self) -> Box<dyn Executor> {
        assert!(!self.units.is_empty(), "ladder must have at least one rung");
        Box::new(LadderExecutor {
            units: self.units.clone(),
            a: Vec::new(),
            b: Vec::new(),
            out: Vec::new(),
        })
    }
}

struct LadderExecutor {
    units: Vec<Arc<dyn crate::arith::ApproxMul>>,
    a: Vec<u64>,
    b: Vec<u64>,
    out: Vec<u64>,
}

impl Executor for LadderExecutor {
    fn execute(&mut self, a: &[i64], b: &[i64]) -> Vec<i64> {
        self.execute_rung(0, a, b)
    }

    fn execute_rung(&mut self, rung: u32, a: &[i64], b: &[i64]) -> Vec<i64> {
        let u = &self.units[(rung as usize).min(self.units.len() - 1)];
        self.a.clear();
        self.a.extend(a.iter().map(|&x| x as u64));
        self.b.clear();
        self.b.extend(b.iter().map(|&x| x as u64));
        self.out.clear();
        self.out.resize(a.len(), 0);
        let (ua, ub) = (&self.a, &self.b);
        crate::util::par::par_chunks_mut(&mut self.out, UNIT_SHARD_LANES, |_c, off, o| {
            u.mul_batch(&ua[off..off + o.len()], &ub[off..off + o.len()], o);
        });
        self.out.iter().map(|&x| x as i64).collect()
    }
}

/// One enqueued request.
pub struct Request {
    /// Caller-unique id (assigned by the coordinator).
    pub id: u64,
    /// First operand vector.
    pub a: Vec<i64>,
    /// Second operand vector (same length as `a`).
    pub b: Vec<i64>,
    /// Channel the per-span replies go back on.
    pub reply: SyncSender<Response>,
    /// Submission time for latency accounting.
    pub t_submit: Instant,
    /// Absolute completion deadline, if the caller set one. Admission
    /// control already ran at enqueue; the field rides along for
    /// observability (admitted requests always execute — see the module
    /// doc's shed policy).
    pub deadline: Option<Instant>,
    /// Accuracy-ladder rung stamped at submit time (the coordinator's
    /// current rung register; 0 with no governor attached). The batcher
    /// keys batches by it, so the unit a request executes on is fixed at
    /// submit — never by worker/batch timing.
    pub rung: u32,
}

/// Reply carrying one span's results, tagged with its position inside the
/// original request (requests split across batches may complete out of
/// order; callers reassemble by offset).
#[derive(Debug)]
pub struct Response {
    /// Id of the request the span belongs to.
    pub id: u64,
    /// offset of `values` within the original request
    pub offset: usize,
    /// Results of this span's lanes.
    pub values: Vec<i64>,
}

/// Why a non-blocking submission did not enter the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The lane's bounded ingress queue is full (backpressure) or closed.
    Full,
    /// Deadline admission control shed the request: the enqueue-time
    /// estimate said the deadline cannot be met given the queue depth.
    Shed,
}

/// Sizing knobs of one coordinator instance.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Fixed batch shape requests are packed into.
    pub batch_capacity: usize,
    /// Deadline after which a short batch is flushed anyway.
    pub max_wait: Duration,
    /// Total executor worker threads, divided across shards (≥ 1 each).
    pub workers: usize,
    /// Bounded ingress queue depth per shard (the backpressure point).
    pub queue_depth: usize,
    /// Independent ingress lanes. `1` = the classic single-leader loop
    /// (the bit-identity oracle); `N` = N queue+batcher+worker-pool lanes
    /// with round-robin routing at the submitting thread.
    pub shards: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batch_capacity: 8192,
            max_wait: Duration::from_micros(200),
            workers: 2,
            queue_depth: 64,
            shards: 1,
        }
    }
}

/// The sharded-lane (or, at `shards == 1`, leader + worker-pool)
/// coordinator.
pub struct Coordinator {
    lanes: Vec<SyncSender<Request>>,
    /// Live counters (shared with all lanes and workers).
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    next_lane: AtomicU64,
    max_wait: Duration,
    /// Accuracy-ladder rung stamped on every submitted request (the QoR
    /// governor's actuator; 0 = cheapest / governor off).
    rung: AtomicU32,
    shutdown: Arc<AtomicBool>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Coordinator {
    /// Spawn every lane (batcher thread + executor threads) and return the
    /// handle callers submit through. Threads join on drop.
    pub fn start(exec: Arc<dyn ExecutorFactory>, cfg: CoordinatorConfig) -> Arc<Self> {
        let shards = cfg.shards.max(1);
        let workers_per_shard = (cfg.workers / shards).max(1);
        let metrics = Arc::new(Metrics::with_shards(shards));
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut lanes = Vec::with_capacity(shards);
        let mut threads = Vec::new();
        for s in 0..shards {
            let (ingress_tx, ingress_rx) = sync_channel::<Request>(cfg.queue_depth);
            let (batch_tx, batch_rx) =
                sync_channel::<(Batch, Vec<PendingSpan>, BatchTicket)>(workers_per_shard * 2);
            let batch_rx = Arc::new(Mutex::new(batch_rx));
            lanes.push(ingress_tx);
            // lane leader: ingest + batch
            {
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let capacity = cfg.batch_capacity;
                let max_wait = cfg.max_wait;
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("rapid-leader-{s}"))
                        .spawn(move || {
                            leader_loop(s, ingress_rx, batch_tx, metrics, shutdown, capacity, max_wait)
                        })
                        .expect("spawn leader"),
                );
            }
            // lane workers
            for w in 0..workers_per_shard {
                let rx = batch_rx.clone();
                let exec = exec.clone();
                let metrics = metrics.clone();
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("rapid-worker-{s}-{w}"))
                        .spawn(move || worker_loop(rx, exec, metrics))
                        .expect("spawn worker"),
                );
            }
        }
        Arc::new(Coordinator {
            lanes,
            metrics,
            next_id: AtomicU64::new(1),
            next_lane: AtomicU64::new(0),
            max_wait: cfg.max_wait,
            rung: AtomicU32::new(0),
            shutdown,
            threads: Mutex::new(threads),
        })
    }

    /// Round-robin lane pick by the submitting thread (the scalable part
    /// of the sharded ingress: no leader serializes routing).
    fn route(&self) -> usize {
        (self.next_lane.fetch_add(1, Ordering::Relaxed) % self.lanes.len() as u64) as usize
    }

    /// Enqueue-time wait estimate for `lane` in ns: worst-case batch
    /// formation linger plus draining everything queued ahead at the
    /// EWMA batch service time (0 until the first batch completes, so a
    /// cold coordinator admits everything with a feasible deadline).
    pub fn estimated_wait_ns(&self, lane: usize) -> u64 {
        let depth = self.metrics.ingress_depth(lane);
        let service = self.metrics.batch_service_ewma_ns();
        self.max_wait.as_nanos() as u64 + (depth + 1) * service
    }

    /// Submit and wait for the reply (blocking backpressure). A request may
    /// be split across batches at capacity boundaries; replies arrive one
    /// per span and are reassembled in order here.
    pub fn call(&self, a: Vec<i64>, b: Vec<i64>) -> Vec<i64> {
        self.call_with_deadline(a, b, None).expect("no deadline, never shed")
    }

    /// [`Self::call`] with optional deadline admission control: `Err(Shed)`
    /// when the enqueue-time estimate says `deadline` cannot be met given
    /// the lane's queue depth (counted in [`Metrics::shed`], never
    /// enqueued, never executed).
    pub fn call_with_deadline(
        &self,
        a: Vec<i64>,
        b: Vec<i64>,
        deadline: Option<Duration>,
    ) -> Result<Vec<i64>, SubmitError> {
        let t_entry = Instant::now();
        let lane = self.route();
        let rung = self.rung.load(Ordering::SeqCst);
        if let Some(d) = deadline {
            if self.estimated_wait_ns(lane) > d.as_nanos() as u64 {
                self.metrics.record_shed(lane);
                trace::record_instant(Category::Request, Phase::Shed, 0, lane as u32, rung);
                return Err(SubmitError::Shed);
            }
        }
        let (tx, rx) = sync_channel(16);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let n = a.len();
        let now = Instant::now();
        let req = Request {
            id,
            a,
            b,
            reply: tx,
            t_submit: now,
            deadline: deadline.map(|d| now + d),
            rung,
        };
        self.metrics.record_request(n);
        self.metrics.ingress_enqueued(lane);
        self.lanes[lane].send(req).expect("coordinator ingress closed");
        trace::record_span(Category::Request, Phase::Submit, id, lane as u32, rung, t_entry, Instant::now());
        let mut out = vec![0i64; n];
        let mut filled = 0usize;
        while filled < n {
            let resp = rx.recv().expect("coordinator dropped reply");
            debug_assert_eq!(resp.id, id);
            let end = resp.offset + resp.values.len();
            out[resp.offset..end].copy_from_slice(&resp.values);
            filled += resp.values.len();
        }
        Ok(out)
    }

    /// Non-blocking submit; `Err` = queue full (backpressure signal).
    /// Replies arrive one per span on the returned channel.
    pub fn try_call_async(&self, a: Vec<i64>, b: Vec<i64>) -> Result<Receiver<Response>, ()> {
        self.try_call_async_with_deadline(a, b, None).map_err(|_| ())
    }

    /// Non-blocking submit with optional deadline admission control —
    /// the open-loop load generator's entry point: `Err(Shed)` when
    /// admission control drops the request, `Err(Full)` on backpressure.
    /// The reply channel is sized for split requests (one reply per span).
    pub fn try_call_async_with_deadline(
        &self,
        a: Vec<i64>,
        b: Vec<i64>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Response>, SubmitError> {
        let t_entry = Instant::now();
        let lane = self.route();
        let rung = self.rung.load(Ordering::SeqCst);
        if let Some(d) = deadline {
            if self.estimated_wait_ns(lane) > d.as_nanos() as u64 {
                self.metrics.record_shed(lane);
                trace::record_instant(Category::Request, Phase::Shed, 0, lane as u32, rung);
                return Err(SubmitError::Shed);
            }
        }
        let n = a.len();
        let (tx, rx) = sync_channel(16);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let req = Request {
            id,
            a,
            b,
            reply: tx,
            t_submit: now,
            deadline: deadline.map(|d| now + d),
            rung,
        };
        self.metrics.ingress_enqueued(lane);
        match self.lanes[lane].try_send(req) {
            Ok(()) => {
                self.metrics.record_request(n);
                trace::record_span(Category::Request, Phase::Submit, id, lane as u32, rung, t_entry, Instant::now());
                Ok(rx)
            }
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.metrics.ingress_dequeued(lane);
                self.metrics.record_rejected(lane);
                Err(SubmitError::Full)
            }
        }
    }

    /// Number of independent ingress lanes.
    pub fn shards(&self) -> usize {
        self.lanes.len()
    }

    /// Rung stamped on requests submitted from now on (the governor's
    /// actuator). In-flight requests keep the rung they were stamped with;
    /// the batcher flushes any open batch at the first request of the new
    /// rung, so no batch ever mixes rungs. Also mirrored into the
    /// `rapid_governor_rung` gauge.
    pub fn set_rung(&self, rung: u32) {
        self.rung.store(rung, Ordering::SeqCst);
        self.metrics.set_governor_rung(rung as u64);
    }

    /// Rung currently stamped on new submissions.
    pub fn current_rung(&self) -> u32 {
        self.rung.load(Ordering::SeqCst)
    }

    /// Signal the lane loops to exit (drop joins the threads).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
        // each leader exits on the shutdown flag (or when its ingress
        // disconnects); its workers exit when the lane's batch channel
        // closes behind it. Joining here keeps tests leak-free.
        for t in self.threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }
}

/// Reply bookkeeping for one span of a batch.
struct PendingSpan {
    reply: SyncSender<Response>,
    id: u64,
    t_submit: Instant,
    /// when the leader dequeued the request — the queue/batch_form phase
    /// boundary (shared by both sides, so the phases telescope exactly)
    t_dequeue: Instant,
    /// offset within the batch
    offset: usize,
    len: usize,
    /// offset within the originating request
    req_offset: usize,
}

/// Per-batch routing metadata riding the dispatch channel: which lane
/// formed the batch, its per-lane sequence number (the batch trace id)
/// and the dispatch instant — the batch_form/execute phase boundary.
struct BatchTicket {
    shard: usize,
    seq: u64,
    t_dispatch: Instant,
}

fn leader_loop(
    shard: usize,
    ingress: Receiver<Request>,
    batch_tx: SyncSender<(Batch, Vec<PendingSpan>, BatchTicket)>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    capacity: usize,
    max_wait: Duration,
) {
    let mut batcher = DynamicBatcher::new(capacity, max_wait);
    let mut pending: Vec<PendingSpan> = Vec::new();
    // per-lane batch sequence (the batch trace id; ids only need to be
    // unique within a lane, the shard label disambiguates across lanes)
    let mut batch_seq: u64 = 0;
    // reusable full-batch buffer: offer_into appends here, so steady-state
    // batch formation never allocates a fresh Vec<Batch>
    let mut emitted: Vec<Batch> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let req = match ingress.recv_timeout(max_wait) {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // drain: flush the open batch and exit
                if let Some(b) = batcher.flush() {
                    let spans = collect_spans(&b, &pending);
                    metrics.record_batch(b.used, capacity);
                    dispatch(&batch_tx, b, spans, &metrics, shard, &mut batch_seq);
                }
                return;
            }
        };
        if let Some(req) = req {
            metrics.ingress_dequeued(shard);
            let t_dequeue = Instant::now();
            // requests larger than the batch are executed in chunks but the
            // reply is assembled by the caller via multiple spans with the
            // same reply channel
            batcher.offer_into(req.id, req.rung, &req.a, &req.b, &mut emitted);
            // spans for this request may appear in several emitted batches;
            // tag each emitted batch with its pending spans
            for b in emitted.drain(..) {
                let spans = spans_for(&b, &req, t_dequeue, &pending);
                metrics.record_batch(b.used, capacity);
                dispatch(&batch_tx, b, spans, &metrics, shard, &mut batch_seq);
            }
            // remember the reply for the (possibly still open) tail span
            pending.push(PendingSpan {
                req_offset: 0,
                reply: req.reply.clone(),
                id: req.id,
                t_submit: req.t_submit,
                t_dequeue,
                offset: 0,
                len: 0,
            });
            // compact: drop pendings whose request can no longer appear in
            // the open batch (they were fully dispatched). Simplest correct
            // policy: keep the most recent 1024.
            if pending.len() > 1024 {
                let keep = pending.len() - 1024;
                pending.drain(..keep);
            }
        }
        if batcher.deadline_expired() || (shutdown.load(Ordering::SeqCst) && batcher.pending() > 0) {
            if let Some(b) = batcher.flush() {
                let spans = collect_spans(&b, &pending);
                metrics.record_batch(b.used, capacity);
                dispatch(&batch_tx, b, spans, &metrics, shard, &mut batch_seq);
            }
        }
    }
}

fn spans_for(b: &Batch, req: &Request, t_dequeue: Instant, pending: &[PendingSpan]) -> Vec<PendingSpan> {
    b.spans
        .iter()
        .map(|(id, off, len, req_off)| {
            let (reply, t, tq) = if *id == req.id {
                (req.reply.clone(), req.t_submit, t_dequeue)
            } else {
                let p = pending.iter().rev().find(|p| p.id == *id).expect("span for unknown request");
                (p.reply.clone(), p.t_submit, p.t_dequeue)
            };
            PendingSpan {
                reply,
                id: *id,
                t_submit: t,
                t_dequeue: tq,
                offset: *off,
                len: *len,
                req_offset: *req_off,
            }
        })
        .collect()
}

fn collect_spans(b: &Batch, pending: &[PendingSpan]) -> Vec<PendingSpan> {
    b.spans
        .iter()
        .map(|(id, off, len, req_off)| {
            let p = pending.iter().rev().find(|p| p.id == *id).expect("span for unknown request");
            PendingSpan {
                reply: p.reply.clone(),
                id: *id,
                t_submit: p.t_submit,
                t_dequeue: p.t_dequeue,
                offset: *off,
                len: *len,
                req_offset: *req_off,
            }
        })
        .collect()
}

fn dispatch(
    tx: &SyncSender<(Batch, Vec<PendingSpan>, BatchTicket)>,
    b: Batch,
    spans: Vec<PendingSpan>,
    metrics: &Metrics,
    shard: usize,
    batch_seq: &mut u64,
) {
    let seq = *batch_seq;
    *batch_seq += 1;
    let t_dispatch = Instant::now();
    trace::record_span(Category::Batch, Phase::BatchForm, seq, shard as u32, b.rung, b.opened_at, t_dispatch);
    metrics.batch_enqueued();
    let _ = tx.send((b, spans, BatchTicket { shard, seq, t_dispatch }));
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<(Batch, Vec<PendingSpan>, BatchTicket)>>>,
    factory: Arc<dyn ExecutorFactory>,
    metrics: Arc<Metrics>,
) {
    let mut exec = factory.make();
    loop {
        let item = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let (batch, spans, ticket) = match item {
            Ok(x) => x,
            Err(_) => return,
        };
        metrics.batch_dequeued();
        let shard32 = ticket.shard as u32;
        let t_pick = Instant::now();
        trace::record_span(Category::Batch, Phase::BatchQueue, ticket.seq, shard32, batch.rung, ticket.t_dispatch, t_pick);
        let out = exec.execute_rung(batch.rung, &batch.a, &batch.b);
        let t_done = Instant::now();
        metrics.record_batch_service(t_done.saturating_duration_since(t_pick));
        trace::record_span(Category::Batch, Phase::BatchExecute, ticket.seq, shard32, batch.rung, t_pick, t_done);
        for s in spans {
            let values = out[s.offset..s.offset + s.len].to_vec();
            // one shared `now` per span: the three phases telescope to the
            // recorded end-to-end latency exactly (no re-reads in between)
            let now = Instant::now();
            metrics.record_phase(
                ServePhase::Queue,
                ticket.shard,
                s.t_dequeue.saturating_duration_since(s.t_submit),
            );
            metrics.record_phase(
                ServePhase::BatchForm,
                ticket.shard,
                ticket.t_dispatch.saturating_duration_since(s.t_dequeue),
            );
            metrics.record_phase(
                ServePhase::Execute,
                ticket.shard,
                now.saturating_duration_since(ticket.t_dispatch),
            );
            metrics.record_latency(now.saturating_duration_since(s.t_submit));
            if trace::enabled() {
                trace::record_span(Category::Request, Phase::Queue, s.id, shard32, batch.rung, s.t_submit, s.t_dequeue);
                trace::record_span(Category::Request, Phase::BatchForm, s.id, shard32, batch.rung, s.t_dequeue, ticket.t_dispatch);
                trace::record_span(Category::Request, Phase::Execute, s.id, shard32, batch.rung, ticket.t_dispatch, now);
            }
            let _ = s.reply.send(Response { id: s.id, offset: s.req_offset, values });
            trace::record_span(Category::Request, Phase::Reply, s.id, shard32, batch.rung, now, Instant::now());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add_exec() -> Arc<dyn ExecutorFactory> {
        Arc::new(FnFactory(|a: &[i64], b: &[i64]| {
            a.iter().zip(b).map(|(x, y)| x + y).collect::<Vec<i64>>()
        }))
    }

    fn small_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            batch_capacity: 16,
            max_wait: Duration::from_micros(100),
            workers: 2,
            queue_depth: 8,
            shards: 1,
        }
    }

    #[test]
    fn call_roundtrip() {
        let c = Coordinator::start(add_exec(), small_cfg());
        let out = c.call(vec![1, 2, 3], vec![10, 20, 30]);
        assert_eq!(out, vec![11, 22, 33]);
    }

    #[test]
    fn call_roundtrip_sharded() {
        let c = Coordinator::start(add_exec(), CoordinatorConfig { shards: 4, ..small_cfg() });
        assert_eq!(c.shards(), 4);
        for i in 0..16i64 {
            // 16 calls round-robin across all 4 lanes
            let out = c.call(vec![i, i + 1], vec![10, 20]);
            assert_eq!(out, vec![i + 10, i + 21]);
        }
    }

    #[test]
    fn many_concurrent_callers_get_their_own_results() {
        for shards in [1usize, 4] {
            let c = Coordinator::start(
                add_exec(),
                CoordinatorConfig { shards, workers: 4, ..small_cfg() },
            );
            let mut handles = Vec::new();
            for t in 0..8i64 {
                let c = c.clone();
                handles.push(std::thread::spawn(move || {
                    for i in 0..50i64 {
                        let a: Vec<i64> = (0..5).map(|j| t * 1000 + i * 10 + j).collect();
                        let b = vec![1i64; 5];
                        let out = c.call(a.clone(), b);
                        let want: Vec<i64> = a.iter().map(|x| x + 1).collect();
                        assert_eq!(out, want);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 400, "shards={shards}");
        }
    }

    #[test]
    fn oversized_request_spans_batches() {
        let c = Coordinator::start(add_exec(), small_cfg());
        let a: Vec<i64> = (0..100).collect();
        let b: Vec<i64> = (0..100).map(|x| 2 * x).collect();
        // oversized requests yield multiple spans; the reply channel gets
        // one Response per span — collect and reassemble by offset.
        let rx = c.try_call_async(a.clone(), b.clone()).unwrap();
        let mut got = vec![0i64; 100];
        let mut filled = 0;
        while filled < 100 {
            let resp = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
            let end = resp.offset + resp.values.len();
            got[resp.offset..end].copy_from_slice(&resp.values);
            filled += resp.values.len();
        }
        let want: Vec<i64> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn batch_unit_executor_serves_mul_and_div() {
        use crate::arith::{ApproxDiv, ApproxMul, ExactDiv, RapidMul};
        let unit = RapidMul::new(16, 10);
        let model = RapidMul::new(16, 10);
        let c = Coordinator::start(Arc::new(BatchMulFactory { unit: Arc::new(unit) }), small_cfg());
        let a = vec![3i64, 58, 1000, 0, 65535];
        let b = vec![7i64, 18, 999, 5, 65535];
        let got = c.call(a.clone(), b.clone());
        for i in 0..a.len() {
            assert_eq!(got[i], model.mul(a[i] as u64, b[i] as u64) as i64, "lane {i}");
        }

        let d = Coordinator::start(
            Arc::new(BatchDivFactory { unit: Arc::new(ExactDiv { n: 8 }) }),
            small_cfg(),
        );
        let da = vec![5000i64, 9, 0, 200];
        let db = vec![77i64, 3, 3, 10];
        let got = d.call(da.clone(), db.clone());
        let dm = ExactDiv { n: 8 };
        for i in 0..da.len() {
            assert_eq!(got[i], dm.div(da[i] as u64, db[i] as u64) as i64, "lane {i}");
        }
    }

    #[test]
    fn sharded_executor_matches_scalar_unit_on_large_batches() {
        use crate::arith::{ApproxMul, RapidMul};
        // one request bigger than UNIT_SHARD_LANES so the executor's
        // parallel fan-out actually engages; replies must equal the
        // scalar unit lane for lane
        let cfg = CoordinatorConfig {
            batch_capacity: 8192,
            max_wait: Duration::from_micros(100),
            workers: 2,
            queue_depth: 8,
            shards: 1,
        };
        let unit = RapidMul::new(16, 10);
        let model = RapidMul::new(16, 10);
        let c = Coordinator::start(Arc::new(BatchMulFactory { unit: Arc::new(unit) }), cfg);
        let n = UNIT_SHARD_LANES * 3 + 17;
        let a: Vec<i64> = (0..n as i64).map(|i| (i * 31) % 65536).collect();
        let b: Vec<i64> = (0..n as i64).map(|i| (i * 77 + 5) % 65536).collect();
        let got = c.call(a.clone(), b.clone());
        for i in (0..n).step_by(397) {
            assert_eq!(got[i], model.mul(a[i] as u64, b[i] as u64) as i64, "lane {i}");
        }
    }

    #[test]
    fn ladder_executor_serves_the_stamped_rung() {
        use crate::arith::{ApproxMul, ExactMul, RapidMul};
        let ladder = LadderMulFactory {
            units: vec![
                Arc::new(RapidMul::new(16, 3)) as Arc<dyn crate::arith::ApproxMul>,
                Arc::new(ExactMul { n: 16 }),
            ],
        };
        let c = Coordinator::start(Arc::new(ladder), small_cfg());
        let cheap = RapidMul::new(16, 3);
        let a = vec![3i64, 58, 1000, 65535];
        let b = vec![7i64, 18, 999, 65535];
        // rung 0 (default): the cheap unit serves
        assert_eq!(c.current_rung(), 0);
        let got = c.call(a.clone(), b.clone());
        for i in 0..a.len() {
            assert_eq!(got[i], cheap.mul(a[i] as u64, b[i] as u64) as i64, "rung0 lane {i}");
        }
        // switch to rung 1: the exact unit serves subsequent requests
        c.set_rung(1);
        assert_eq!(c.current_rung(), 1);
        let got = c.call(a.clone(), b.clone());
        for i in 0..a.len() {
            assert_eq!(got[i], (a[i] * b[i]), "rung1 lane {i}");
        }
        // out-of-range rungs clamp to the most accurate unit
        c.set_rung(9);
        let got = c.call(a.clone(), b.clone());
        assert_eq!(got[2], a[2] * b[2]);
        assert_eq!(c.metrics.governor_rung(), 9);
    }

    #[test]
    fn padding_is_accounted() {
        let c = Coordinator::start(add_exec(), small_cfg());
        let _ = c.call(vec![1, 2, 3], vec![4, 5, 6]);
        // 3 elements in a 16-batch → 13 padded
        assert_eq!(c.metrics.padded_elements.load(Ordering::Relaxed), 13);
    }

    #[test]
    fn impossible_deadline_is_shed_before_enqueue() {
        let c = Coordinator::start(add_exec(), small_cfg());
        // zero deadline < max_wait floor of the estimate → always shed
        let r = c.call_with_deadline(vec![1, 2], vec![3, 4], Some(Duration::ZERO));
        assert_eq!(r, Err(SubmitError::Shed));
        assert_eq!(c.metrics.shed.load(Ordering::Relaxed), 1);
        // shed requests are not counted as submitted
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 0);
        // a generous deadline passes admission and completes
        let r = c.call_with_deadline(vec![1, 2], vec![3, 4], Some(Duration::from_secs(5)));
        assert_eq!(r, Ok(vec![4, 6]));
        assert_eq!(c.metrics.shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn estimated_wait_grows_with_queue_depth() {
        let c = Coordinator::start(add_exec(), small_cfg());
        let base = c.estimated_wait_ns(0);
        assert!(base >= 100_000, "max_wait floor: {base}");
        // simulate a measured service time and queued requests: the
        // estimate must grow linearly with depth
        c.metrics.record_batch_service(Duration::from_micros(500));
        let d0 = c.estimated_wait_ns(0);
        c.metrics.ingress_enqueued(0);
        c.metrics.ingress_enqueued(0);
        let d2 = c.estimated_wait_ns(0);
        assert_eq!(d2 - d0, 2 * 500_000);
        c.metrics.ingress_dequeued(0);
        c.metrics.ingress_dequeued(0);
    }
}
