//! Pipeline scheduler model (Fig. 11 / Fig. 12): cycle-accurate simulation
//! of an application kernel graph whose mul/div units are non-pipelined or
//! S-stage pipelined RAPID / accurate circuits.
//!
//! Each kernel is a stream stage with an initiation interval (II) of one
//! unit-operation per cycle once the unit pipeline is full; non-pipelined
//! units stall the stage for their full latency per operation. The model
//! reports end-to-end latency of one item and steady-state throughput —
//! the two axes of the paper's Fig. 12 Pareto plot.

/// One arithmetic unit's timing as seen by the scheduler.
#[derive(Clone, Copy, Debug)]
pub struct UnitTiming {
    /// clock period the unit can sustain (ns)
    pub clock_ns: f64,
    /// pipeline depth (1 = combinational / non-pipelined)
    pub stages: usize,
}

impl UnitTiming {
    /// Cycles between successive independent ops (II).
    pub fn initiation_interval(&self) -> usize {
        if self.stages <= 1 {
            1 // combinational unit registered at the kernel boundary
        } else {
            1 // fully pipelined: one per cycle
        }
    }

    /// Cycles from operand issue to result (the pipeline depth).
    pub fn latency_cycles(&self) -> usize {
        self.stages.max(1)
    }
}

/// One application kernel: `ops` unit-operations per input item, through a
/// unit with `timing`. Kernels run as a chained stream (paper §V-B
/// "streaming approach", no function pipelining pragmas).
#[derive(Clone, Debug)]
pub struct KernelStage {
    /// Stage label (matches the kernel census names).
    pub name: String,
    /// Unit-operations issued per input item.
    pub ops_per_item: usize,
    /// Timing of the unit instance the stage runs on.
    pub timing: UnitTiming,
}

/// Latency/throughput of the kernel chain.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    /// ns for one item to traverse the empty pipeline
    pub latency_ns: f64,
    /// items per µs in steady state
    pub throughput_per_us: f64,
    /// the system clock: slowest unit's clock (one clock domain, like the
    /// paper's HLS implementation)
    pub clock_ns: f64,
}

/// Analytic schedule: system clock = max unit clock; a kernel needs
/// `ops × II + (stages − 1)` cycles for one item; steady-state item rate is
/// bounded by the slowest kernel's `ops × II` cycles.
pub fn schedule(stages: &[KernelStage]) -> ScheduleReport {
    assert!(!stages.is_empty());
    let clock = stages.iter().map(|s| s.timing.clock_ns).fold(0.0f64, f64::max);
    let mut latency_cycles = 0usize;
    let mut bottleneck_cycles = 0usize;
    for s in stages {
        let ii = s.timing.initiation_interval();
        let fill = s.timing.latency_cycles() - 1;
        let per_item = s.ops_per_item * ii + fill;
        latency_cycles += per_item;
        bottleneck_cycles = bottleneck_cycles.max(s.ops_per_item * ii);
    }
    ScheduleReport {
        latency_ns: latency_cycles as f64 * clock,
        throughput_per_us: 1e3 / (bottleneck_cycles as f64 * clock),
        clock_ns: clock,
    }
}

/// Pareto front extraction over (latency, throughput) points — Fig. 12.
/// Returns indices of configurations not dominated by any other
/// (lower latency AND higher throughput dominates).
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut keep = Vec::new();
    'outer: for (i, &(lat_i, tput_i)) in points.iter().enumerate() {
        for (j, &(lat_j, tput_j)) in points.iter().enumerate() {
            if i != j && lat_j <= lat_i && tput_j >= tput_i && (lat_j < lat_i || tput_j > tput_i) {
                continue 'outer;
            }
        }
        keep.push(i);
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, ops: usize, clock: f64, stages: usize) -> KernelStage {
        KernelStage {
            name: name.into(),
            ops_per_item: ops,
            timing: UnitTiming { clock_ns: clock, stages },
        }
    }

    #[test]
    fn pipelined_unit_raises_throughput_but_latency() {
        // One kernel, 64 ops/item: non-pipelined at 6 ns vs 4-stage at 2 ns.
        let np = schedule(&[stage("k", 64, 6.0, 1)]);
        let p4 = schedule(&[stage("k", 64, 2.0, 4)]);
        assert!(p4.throughput_per_us > np.throughput_per_us * 2.0);
        // fill cycles add latency but the faster clock can offset; with
        // equal clocks latency must grow:
        let p4_same_clk = schedule(&[stage("k", 64, 6.0, 4)]);
        assert!(p4_same_clk.latency_ns > np.latency_ns);
    }

    #[test]
    fn slowest_kernel_bounds_throughput() {
        let r = schedule(&[
            stage("light", 8, 3.0, 2),
            stage("heavy", 100, 3.0, 2),
            stage("mid", 20, 3.0, 2),
        ]);
        let heavy_only = schedule(&[stage("heavy", 100, 3.0, 2)]);
        assert!((r.throughput_per_us - heavy_only.throughput_per_us).abs() < 1e-9);
    }

    #[test]
    fn pareto_filters_dominated() {
        // (latency, throughput)
        let pts = vec![(10.0, 5.0), (12.0, 4.0), (8.0, 6.0), (9.0, 2.0)];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![2], "only (8,6) is non-dominated");
        let pts2 = vec![(10.0, 5.0), (20.0, 9.0)];
        assert_eq!(pareto_front(&pts2).len(), 2, "trade-off points both kept");
    }
}
