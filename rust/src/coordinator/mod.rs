//! Layer-3 streaming coordinator — the serving shell around the AOT
//! artifacts (DESIGN.md §2).
//!
//! The paper's units target stream applications "constantly fed with a
//! bulk of data"; the coordinator provides exactly that runtime: a
//! bounded-queue router with backpressure, a dynamic batcher that packs
//! requests to the artifact's compiled batch shape, a std-thread worker
//! pool executing on PJRT, per-stage metrics, and a pipeline scheduler
//! mirroring the 2/3/4-stage units for the Fig. 11/12 study.

pub mod batcher;
pub mod metrics;
pub mod pipeline_sched;
pub mod router;
#[cfg(feature = "pjrt")]
pub mod cli;

pub use batcher::{Batch, DynamicBatcher};
pub use metrics::Metrics;
pub use router::{BatchDivFactory, BatchMulFactory, Coordinator, Request, Response};
