//! Layer-3 streaming coordinator — the serving shell around the AOT
//! artifacts (DESIGN.md §2).
//!
//! The paper's units target stream applications "constantly fed with a
//! bulk of data"; the coordinator provides exactly that runtime: a
//! sharded ingress (N independent queue + batcher + worker-pool lanes,
//! requests routed round-robin by the submitting thread; `shards = 1` is
//! the classic single-leader oracle) with backpressure and deadline
//! admission control, a dynamic batcher that packs requests to the
//! artifact's compiled batch shape, std-thread worker pools executing on
//! PJRT or the in-process functional units, Prometheus-style metrics
//! ([`Metrics::metrics_text`]), a deterministic open-loop load generator
//! ([`loadgen`], `rapid serve-bench`) and a pipeline scheduler mirroring
//! the 2/3/4-stage units for the Fig. 11/12 study.
//!
//! Closing the loop on top of that shell sits the QoR governor
//! ([`governor`]): requests are stamped with an accuracy-ladder rung at
//! submit time, batches never mix rungs, and a pure hysteresis policy
//! steps the served rung along a cheapest→most-accurate ladder from
//! windowed shadow-QoR and load signals — driven by phase-shifting
//! replayable workloads ([`scenario`], `rapid serve-bench --governor`).

pub mod batcher;
pub mod governor;
pub mod loadgen;
pub mod metrics;
pub mod pipeline_sched;
pub mod router;
pub mod scenario;
#[cfg(feature = "pjrt")]
pub mod cli;

pub use batcher::{Batch, DynamicBatcher};
pub use governor::{App, Governor, GovernorConfig, GovernorTrace, Ladder, SwitchReason, Transition, WindowObs};
pub use metrics::Metrics;
pub use router::{
    BatchDivFactory, BatchMulFactory, Coordinator, CoordinatorConfig, LadderMulFactory, Request,
    Response, SubmitError,
};
pub use scenario::{Phase, Regime, ScenarioConfig, ScenarioReport, run_scenario};
