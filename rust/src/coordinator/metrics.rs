//! Lock-light metrics shared by the coordinator's threads: request /
//! element counters, latency histogram, queue depth gauges (per-shard
//! ingress + the dispatch channel), the deadline-shed counter and an EWMA
//! of batch service time (the admission controller's drain estimate).
//! [`Metrics::metrics_text`] dumps everything in the Prometheus text
//! exposition format for scraping / the serve CLI.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Fixed log2 latency histogram (ns buckets from 1µs to ~4s).
const BUCKETS: usize = 24;

/// Render an f64 sample value in the Prometheus text exposition format:
/// finite values print plainly, non-finite map to `+Inf`/`-Inf`/`NaN`
/// (windowed PSNR is `+Inf` when the sampled lanes were error-free).
fn prom_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Counters and latency histogram shared by leaders, workers and callers.
#[derive(Default)]
pub struct Metrics {
    /// Requests submitted.
    pub requests: AtomicU64,
    /// Operand elements submitted across all requests.
    pub elements: AtomicU64,
    /// Batches dispatched to workers.
    pub batches: AtomicU64,
    /// Zero-padding elements added to short batches.
    pub padded_elements: AtomicU64,
    /// Requests rejected by backpressure (`try_submit` on a full queue).
    pub rejected: AtomicU64,
    /// Requests shed by deadline admission control (the enqueue-time
    /// estimate said the deadline could not be met given queue depth).
    pub shed: AtomicU64,
    /// Per-shard ingress queue depth gauges (requests currently enqueued
    /// and not yet picked up by the shard's batching loop).
    ingress_depth: Vec<AtomicU64>,
    /// Batches currently sitting in dispatch channels awaiting a worker.
    batch_queue_depth: AtomicU64,
    /// EWMA of worker batch execution time in ns (0 until the first batch
    /// completes); feeds the admission controller's drain estimate.
    batch_service_ewma_ns: AtomicU64,
    /// Accuracy-ladder rung currently being served (0 = cheapest /
    /// governor off) — mirrors the coordinator's rung register.
    governor_rung: AtomicU64,
    /// Rung switches the governor has committed.
    governor_switches: AtomicU64,
    /// Decision windows the governor has closed.
    governor_windows: AtomicU64,
    /// Last closed window's QoR observation (f64 bits; 0.0 before the
    /// first window). Higher is better on every app metric.
    governor_window_qor_bits: AtomicU64,
    hist: [AtomicU64; BUCKETS],
    lat_sum_ns: AtomicU64,
    lat_count: AtomicU64,
}

impl Metrics {
    /// All-zero metrics with a single ingress gauge (the classic
    /// single-leader shape).
    pub fn new() -> Self {
        Self::with_shards(1)
    }

    /// All-zero metrics with one ingress queue depth gauge per shard.
    pub fn with_shards(shards: usize) -> Self {
        Metrics {
            ingress_depth: (0..shards.max(1)).map(|_| AtomicU64::new(0)).collect(),
            ..Metrics::default()
        }
    }

    /// Number of ingress gauges (== the coordinator's shard count).
    pub fn shards(&self) -> usize {
        self.ingress_depth.len()
    }

    /// Count one submitted request of `elements` operand lanes.
    pub fn record_request(&self, elements: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elements as u64, Ordering::Relaxed);
    }

    /// Count one dispatched batch (`used` live lanes of `capacity`).
    pub fn record_batch(&self, used: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_elements.fetch_add((capacity - used) as u64, Ordering::Relaxed);
    }

    /// Record one span's submit-to-reply latency.
    pub fn record_latency(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
        let bucket = (63 - (ns.max(1024)).leading_zeros() as usize - 10).min(BUCKETS - 1);
        self.hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Count one backpressure rejection.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one deadline-shed request (admission control said no).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A request entered shard `s`'s ingress queue.
    pub fn ingress_enqueued(&self, s: usize) {
        if let Some(g) = self.ingress_depth.get(s) {
            g.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A request left shard `s`'s ingress queue (picked up for batching).
    pub fn ingress_dequeued(&self, s: usize) {
        if let Some(g) = self.ingress_depth.get(s) {
            // saturating: a racing reader must never observe a wrapped
            // gauge; enqueue/dequeue pairing keeps this exact in practice
            let _ = g.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
        }
    }

    /// Current ingress queue depth of shard `s` (0 for unknown shards).
    pub fn ingress_depth(&self, s: usize) -> u64 {
        self.ingress_depth.get(s).map_or(0, |g| g.load(Ordering::Relaxed))
    }

    /// A batch entered a dispatch channel.
    pub fn batch_enqueued(&self) {
        self.batch_queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker picked a batch out of a dispatch channel.
    pub fn batch_dequeued(&self) {
        let _ = self.batch_queue_depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(1))
        });
    }

    /// Batches currently awaiting a worker across all dispatch channels.
    pub fn batch_queue_depth(&self) -> u64 {
        self.batch_queue_depth.load(Ordering::Relaxed)
    }

    /// Fold one batch execution time into the service-time EWMA
    /// (`new = (3·old + sample) / 4`; the first sample seeds it).
    pub fn record_batch_service(&self, d: Duration) {
        let ns = (d.as_nanos() as u64).max(1);
        let _ = self.batch_service_ewma_ns.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |old| Some(if old == 0 { ns } else { (3 * old + ns) / 4 }),
        );
    }

    /// EWMA batch service time in ns (0 before the first batch).
    pub fn batch_service_ewma_ns(&self) -> u64 {
        self.batch_service_ewma_ns.load(Ordering::Relaxed)
    }

    /// Set the served-rung gauge (the coordinator's `set_rung` mirrors
    /// its rung register here).
    pub fn set_governor_rung(&self, rung: u64) {
        self.governor_rung.store(rung, Ordering::Relaxed);
    }

    /// Accuracy-ladder rung currently being served.
    pub fn governor_rung(&self) -> u64 {
        self.governor_rung.load(Ordering::Relaxed)
    }

    /// Count one committed governor switch.
    pub fn record_governor_switch(&self) {
        self.governor_switches.fetch_add(1, Ordering::Relaxed);
    }

    /// Rung switches the governor has committed.
    pub fn governor_switches(&self) -> u64 {
        self.governor_switches.load(Ordering::Relaxed)
    }

    /// Close one governor decision window with its QoR observation
    /// (bumps the window counter and sets the last-window QoR gauge).
    pub fn record_governor_window(&self, qor: f64) {
        self.governor_windows.fetch_add(1, Ordering::Relaxed);
        self.governor_window_qor_bits.store(qor.to_bits(), Ordering::Relaxed);
    }

    /// Decision windows the governor has closed.
    pub fn governor_windows(&self) -> u64 {
        self.governor_windows.load(Ordering::Relaxed)
    }

    /// Last closed window's QoR observation (0.0 before the first window).
    pub fn governor_window_qor(&self) -> f64 {
        f64::from_bits(self.governor_window_qor_bits.load(Ordering::Relaxed))
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the containing bucket).
    pub fn latency_percentile_ns(&self, p: f64) -> u64 {
        let total: u64 = self.hist.iter().map(|h| h.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, h) in self.hist.iter().enumerate() {
            acc += h.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 10 + 1);
            }
        }
        1u64 << (BUCKETS + 10)
    }

    /// Median span latency in ns (histogram upper bound).
    pub fn p50_ns(&self) -> u64 {
        self.latency_percentile_ns(0.5)
    }

    /// 99th-percentile span latency in ns (histogram upper bound).
    pub fn p99_ns(&self) -> u64 {
        self.latency_percentile_ns(0.99)
    }

    /// 99.9th-percentile span latency in ns (histogram upper bound) —
    /// the tail the open-loop load bench tracks per rate rung.
    pub fn p999_ns(&self) -> u64 {
        self.latency_percentile_ns(0.999)
    }

    /// Mean span latency in ns (0 before any reply).
    pub fn mean_latency_ns(&self) -> f64 {
        let n = self.lat_count.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.lat_sum_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// One-line human-readable dump of every counter.
    pub fn summary(&self) -> String {
        format!(
            "requests={} elements={} batches={} padding={} rejected={} shed={} \
             mean_lat={:.1}µs p50={:.1}µs p99={:.1}µs p999={:.1}µs",
            self.requests.load(Ordering::Relaxed),
            self.elements.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.padded_elements.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.mean_latency_ns() / 1e3,
            self.p50_ns() as f64 / 1e3,
            self.p99_ns() as f64 / 1e3,
            self.p999_ns() as f64 / 1e3,
        )
    }

    /// Prometheus text-exposition dump of every counter, gauge and the
    /// latency summary — what a `/metrics` endpoint would serve, printed
    /// by `rapid serve` / `rapid serve-bench` after a run.
    pub fn metrics_text(&self) -> String {
        let mut s = String::new();
        let counter = |s: &mut String, name: &str, help: &str, v: u64| {
            s.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        counter(&mut s, "rapid_requests_total", "Requests submitted.", self.requests.load(Ordering::Relaxed));
        counter(&mut s, "rapid_elements_total", "Operand elements submitted.", self.elements.load(Ordering::Relaxed));
        counter(&mut s, "rapid_batches_total", "Batches dispatched to workers.", self.batches.load(Ordering::Relaxed));
        counter(&mut s, "rapid_padded_elements_total", "Zero-padding elements in short batches.", self.padded_elements.load(Ordering::Relaxed));
        counter(&mut s, "rapid_rejected_total", "Requests rejected by backpressure.", self.rejected.load(Ordering::Relaxed));
        counter(&mut s, "rapid_shed_total", "Requests shed by deadline admission control.", self.shed.load(Ordering::Relaxed));
        s.push_str("# HELP rapid_ingress_queue_depth Requests waiting in a shard's ingress queue.\n");
        s.push_str("# TYPE rapid_ingress_queue_depth gauge\n");
        for (i, g) in self.ingress_depth.iter().enumerate() {
            s.push_str(&format!(
                "rapid_ingress_queue_depth{{shard=\"{i}\"}} {}\n",
                g.load(Ordering::Relaxed)
            ));
        }
        s.push_str("# HELP rapid_batch_queue_depth Batches awaiting a worker in dispatch channels.\n");
        s.push_str("# TYPE rapid_batch_queue_depth gauge\n");
        s.push_str(&format!("rapid_batch_queue_depth {}\n", self.batch_queue_depth()));
        s.push_str("# HELP rapid_batch_service_ewma_ns EWMA batch execution time (ns).\n");
        s.push_str("# TYPE rapid_batch_service_ewma_ns gauge\n");
        s.push_str(&format!("rapid_batch_service_ewma_ns {}\n", self.batch_service_ewma_ns()));
        counter(
            &mut s,
            "rapid_governor_switches_total",
            "Accuracy-rung switches committed by the QoR governor.",
            self.governor_switches(),
        );
        counter(
            &mut s,
            "rapid_governor_windows_total",
            "Decision windows closed by the QoR governor.",
            self.governor_windows(),
        );
        s.push_str("# HELP rapid_governor_rung Accuracy-ladder rung currently served (0 = cheapest).\n");
        s.push_str("# TYPE rapid_governor_rung gauge\n");
        s.push_str(&format!("rapid_governor_rung {}\n", self.governor_rung()));
        s.push_str("# HELP rapid_governor_window_qor Last decision window's QoR observation (higher is better).\n");
        s.push_str("# TYPE rapid_governor_window_qor gauge\n");
        s.push_str(&format!("rapid_governor_window_qor {}\n", prom_f64(self.governor_window_qor())));
        s.push_str("# HELP rapid_latency_ns Span submit-to-reply latency (ns).\n");
        s.push_str("# TYPE rapid_latency_ns summary\n");
        s.push_str(&format!("rapid_latency_ns{{quantile=\"0.5\"}} {}\n", self.p50_ns()));
        s.push_str(&format!("rapid_latency_ns{{quantile=\"0.99\"}} {}\n", self.p99_ns()));
        s.push_str(&format!("rapid_latency_ns{{quantile=\"0.999\"}} {}\n", self.p999_ns()));
        s.push_str(&format!("rapid_latency_ns_sum {}\n", self.lat_sum_ns.load(Ordering::Relaxed)));
        s.push_str(&format!("rapid_latency_ns_count {}\n", self.lat_count.load(Ordering::Relaxed)));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100);
        m.record_request(28);
        m.record_batch(100, 128);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.elements.load(Ordering::Relaxed), 128);
        assert_eq!(m.padded_elements.load(Ordering::Relaxed), 28);
    }

    #[test]
    fn percentile_monotone() {
        let m = Metrics::new();
        for us in [5u64, 10, 20, 40, 80, 160, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert!(m.p50_ns() <= m.p99_ns());
        assert!(m.p99_ns() <= m.p999_ns());
        assert!(m.mean_latency_ns() > 0.0);
    }

    #[test]
    fn gauges_track_depth_and_saturate() {
        let m = Metrics::with_shards(3);
        assert_eq!(m.shards(), 3);
        m.ingress_enqueued(1);
        m.ingress_enqueued(1);
        m.ingress_dequeued(1);
        assert_eq!(m.ingress_depth(1), 1);
        assert_eq!(m.ingress_depth(0), 0);
        // dequeue on an empty gauge saturates at zero, never wraps
        m.ingress_dequeued(0);
        assert_eq!(m.ingress_depth(0), 0);
        // out-of-range shards are inert
        m.ingress_enqueued(9);
        assert_eq!(m.ingress_depth(9), 0);
        m.batch_enqueued();
        m.batch_enqueued();
        m.batch_dequeued();
        assert_eq!(m.batch_queue_depth(), 1);
        m.batch_dequeued();
        m.batch_dequeued();
        assert_eq!(m.batch_queue_depth(), 0);
    }

    #[test]
    fn service_ewma_seeds_then_smooths() {
        let m = Metrics::new();
        assert_eq!(m.batch_service_ewma_ns(), 0);
        m.record_batch_service(Duration::from_nanos(1000));
        assert_eq!(m.batch_service_ewma_ns(), 1000);
        m.record_batch_service(Duration::from_nanos(2000));
        // (3*1000 + 2000) / 4 = 1250
        assert_eq!(m.batch_service_ewma_ns(), 1250);
    }

    #[test]
    fn governor_gauges_roundtrip() {
        let m = Metrics::new();
        assert_eq!(m.governor_rung(), 0);
        assert_eq!(m.governor_switches(), 0);
        assert_eq!(m.governor_window_qor(), 0.0);
        m.set_governor_rung(3);
        m.record_governor_switch();
        m.record_governor_window(41.25);
        m.record_governor_window(f64::INFINITY);
        assert_eq!(m.governor_rung(), 3);
        assert_eq!(m.governor_switches(), 1);
        assert_eq!(m.governor_windows(), 2);
        assert!(m.governor_window_qor().is_infinite());
        let t = m.metrics_text();
        assert!(t.contains("rapid_governor_rung 3"), "{t}");
        assert!(t.contains("rapid_governor_switches_total 1"), "{t}");
        assert!(t.contains("rapid_governor_windows_total 2"), "{t}");
        assert!(t.contains("rapid_governor_window_qor +Inf"), "{t}");
        assert!(t.contains("# TYPE rapid_governor_rung gauge"), "{t}");
        assert!(t.contains("# TYPE rapid_governor_switches_total counter"), "{t}");
    }

    #[test]
    fn metrics_text_is_prometheus_shaped() {
        let m = Metrics::with_shards(2);
        m.record_request(10);
        m.record_shed();
        m.ingress_enqueued(1);
        m.record_latency(Duration::from_micros(50));
        let t = m.metrics_text();
        assert!(t.contains("# TYPE rapid_requests_total counter"), "{t}");
        assert!(t.contains("rapid_requests_total 1"), "{t}");
        assert!(t.contains("rapid_shed_total 1"), "{t}");
        assert!(t.contains("rapid_ingress_queue_depth{shard=\"0\"} 0"), "{t}");
        assert!(t.contains("rapid_ingress_queue_depth{shard=\"1\"} 1"), "{t}");
        assert!(t.contains("rapid_latency_ns{quantile=\"0.999\"}"), "{t}");
        assert!(t.contains("rapid_latency_ns_count 1"), "{t}");
        // every non-comment line is "name[{labels}] value"
        for line in t.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }
}
