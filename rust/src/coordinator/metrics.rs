//! Lock-light metrics shared by the coordinator's threads: request /
//! element counters, latency histogram, queue depth gauges.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Fixed log2 latency histogram (ns buckets from 1µs to ~4s).
const BUCKETS: usize = 24;

/// Counters and latency histogram shared by leader, workers and callers.
#[derive(Default)]
pub struct Metrics {
    /// Requests submitted.
    pub requests: AtomicU64,
    /// Operand elements submitted across all requests.
    pub elements: AtomicU64,
    /// Batches dispatched to workers.
    pub batches: AtomicU64,
    /// Zero-padding elements added to short batches.
    pub padded_elements: AtomicU64,
    /// Requests rejected by backpressure (`try_submit` on a full queue).
    pub rejected: AtomicU64,
    hist: [AtomicU64; BUCKETS],
    lat_sum_ns: AtomicU64,
    lat_count: AtomicU64,
}

impl Metrics {
    /// All-zero metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one submitted request of `elements` operand lanes.
    pub fn record_request(&self, elements: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elements as u64, Ordering::Relaxed);
    }

    /// Count one dispatched batch (`used` live lanes of `capacity`).
    pub fn record_batch(&self, used: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_elements.fetch_add((capacity - used) as u64, Ordering::Relaxed);
    }

    /// Record one span's submit-to-reply latency.
    pub fn record_latency(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
        let bucket = (63 - (ns.max(1024)).leading_zeros() as usize - 10).min(BUCKETS - 1);
        self.hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Count one backpressure rejection.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the containing bucket).
    pub fn latency_percentile_ns(&self, p: f64) -> u64 {
        let total: u64 = self.hist.iter().map(|h| h.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, h) in self.hist.iter().enumerate() {
            acc += h.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 10 + 1);
            }
        }
        1u64 << (BUCKETS + 10)
    }

    /// Mean span latency in ns (0 before any reply).
    pub fn mean_latency_ns(&self) -> f64 {
        let n = self.lat_count.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.lat_sum_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// One-line human-readable dump of every counter.
    pub fn summary(&self) -> String {
        format!(
            "requests={} elements={} batches={} padding={} rejected={} mean_lat={:.1}µs p50={:.1}µs p99={:.1}µs",
            self.requests.load(Ordering::Relaxed),
            self.elements.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.padded_elements.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.mean_latency_ns() / 1e3,
            self.latency_percentile_ns(0.5) as f64 / 1e3,
            self.latency_percentile_ns(0.99) as f64 / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100);
        m.record_request(28);
        m.record_batch(100, 128);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.elements.load(Ordering::Relaxed), 128);
        assert_eq!(m.padded_elements.load(Ordering::Relaxed), 28);
    }

    #[test]
    fn percentile_monotone() {
        let m = Metrics::new();
        for us in [5u64, 10, 20, 40, 80, 160, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert!(m.latency_percentile_ns(0.5) <= m.latency_percentile_ns(0.99));
        assert!(m.mean_latency_ns() > 0.0);
    }
}
