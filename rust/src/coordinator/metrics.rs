//! Lock-light metrics shared by the coordinator's threads: request /
//! element counters, the end-to-end latency histogram plus true bucketed
//! per-phase histograms (`queue` / `batch_form` / `execute`, per shard),
//! queue depth gauges (per-shard ingress + the dispatch channel),
//! admission-refusal counters split by reason (`deadline` sheds vs
//! `queue_full` backpressure) and an EWMA of batch service time (the
//! admission controller's drain estimate). [`Metrics::metrics_text`]
//! dumps everything in the Prometheus text exposition format for
//! scraping / the serve CLI.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Fixed log2 latency histogram (ns buckets from 1µs to ~4s).
const BUCKETS: usize = 24;

/// Histogram bucket of a nanosecond latency: bucket `i` holds
/// `[2^(10+i), 2^(11+i))` ns, with everything below 1µs clamped into
/// bucket 0 and everything from `2^33` ns (~8.6s) up in the last.
fn bucket_of(ns: u64) -> usize {
    (63 - (ns.max(1024)).leading_zeros() as usize - 10).min(BUCKETS - 1)
}

/// Upper bound of histogram bucket `i` in ns — the value every
/// percentile read quantizes up to.
fn bucket_upper_ns(i: usize) -> u64 {
    1u64 << (i + 11)
}

/// Bucket-upper-bound percentile over one merged histogram: the bound
/// of the first bucket whose cumulative count reaches `ceil(total·p)`;
/// 0 when the histogram is empty (see
/// [`Metrics::latency_percentile_ns`] for the full contract).
fn hist_percentile_ns(counts: &[u64; BUCKETS], p: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * p).ceil() as u64;
    let mut acc = 0;
    for (i, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return bucket_upper_ns(i);
        }
    }
    bucket_upper_ns(BUCKETS - 1)
}

/// The request lifecycle phases with a bucketed serving histogram.
/// Their spans partition submit→reply exactly (each boundary instant is
/// measured once and shared), so per-phase sums reconcile with the
/// end-to-end `rapid_latency_ns` summary exactly on `_sum` and within
/// one bucket on quantiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServePhase {
    /// Enqueue to leader dequeue (ingress queue wait).
    Queue,
    /// Leader dequeue to batch dispatch (batch formation wait).
    BatchForm,
    /// Batch dispatch to reply ready (worker queue + execution).
    Execute,
}

impl ServePhase {
    /// All phases, exposition order.
    pub const ALL: [ServePhase; 3] = [ServePhase::Queue, ServePhase::BatchForm, ServePhase::Execute];

    /// The `phase` label value in `rapid_phase_ns`.
    pub fn label(self) -> &'static str {
        match self {
            ServePhase::Queue => "queue",
            ServePhase::BatchForm => "batch_form",
            ServePhase::Execute => "execute",
        }
    }

    fn index(self) -> usize {
        match self {
            ServePhase::Queue => 0,
            ServePhase::BatchForm => 1,
            ServePhase::Execute => 2,
        }
    }
}

/// One phase × shard latency histogram (same bucket layout as the
/// end-to-end histogram, plus an exact sum for `_sum`).
#[derive(Default)]
struct PhaseHist {
    hist: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl PhaseHist {
    fn record(&self, ns: u64) {
        self.hist[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }
}

/// Quantized per-phase latency snapshot (histogram upper bounds, summed
/// across shards) — the phase-attribution row benches and reports print
/// next to the end-to-end percentiles.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseBreakdown {
    /// Queue-wait median, ns.
    pub queue_p50_ns: u64,
    /// Queue-wait 99th percentile, ns.
    pub queue_p99_ns: u64,
    /// Batch-formation median, ns.
    pub batch_form_p50_ns: u64,
    /// Batch-formation 99th percentile, ns.
    pub batch_form_p99_ns: u64,
    /// Execute median, ns.
    pub execute_p50_ns: u64,
    /// Execute 99th percentile, ns.
    pub execute_p99_ns: u64,
}

/// Render an f64 sample value in the Prometheus text exposition format:
/// finite values print plainly, non-finite map to `+Inf`/`-Inf`/`NaN`
/// (windowed PSNR is `+Inf` when the sampled lanes were error-free).
fn prom_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Counters and latency histogram shared by leaders, workers and callers.
#[derive(Default)]
pub struct Metrics {
    /// Requests submitted.
    pub requests: AtomicU64,
    /// Operand elements submitted across all requests.
    pub elements: AtomicU64,
    /// Batches dispatched to workers.
    pub batches: AtomicU64,
    /// Zero-padding elements added to short batches.
    pub padded_elements: AtomicU64,
    /// Requests rejected by backpressure (`try_submit` on a full queue).
    pub rejected: AtomicU64,
    /// Requests shed by deadline admission control (the enqueue-time
    /// estimate said the deadline could not be met given queue depth).
    pub shed: AtomicU64,
    /// Per-shard deadline sheds (`rapid_shed_reason_total{reason="deadline"}`;
    /// sums to `shed`).
    shed_deadline: Vec<AtomicU64>,
    /// Per-shard backpressure rejections
    /// (`rapid_shed_reason_total{reason="queue_full"}`; sums to `rejected`).
    shed_queue_full: Vec<AtomicU64>,
    /// Per-shard ingress queue depth gauges (requests currently enqueued
    /// and not yet picked up by the shard's batching loop).
    ingress_depth: Vec<AtomicU64>,
    /// Per-shard [queue, batch_form, execute] phase histograms
    /// (`rapid_phase_ns`), indexed by [`ServePhase::index`].
    phase_hists: Vec<[PhaseHist; 3]>,
    /// Batches currently sitting in dispatch channels awaiting a worker.
    batch_queue_depth: AtomicU64,
    /// EWMA of worker batch execution time in ns (0 until the first batch
    /// completes); feeds the admission controller's drain estimate.
    batch_service_ewma_ns: AtomicU64,
    /// Accuracy-ladder rung currently being served (0 = cheapest /
    /// governor off) — mirrors the coordinator's rung register.
    governor_rung: AtomicU64,
    /// Rung switches the governor has committed.
    governor_switches: AtomicU64,
    /// Decision windows the governor has closed.
    governor_windows: AtomicU64,
    /// Last closed window's QoR observation (f64 bits; 0.0 before the
    /// first window). Higher is better on every app metric.
    governor_window_qor_bits: AtomicU64,
    hist: [AtomicU64; BUCKETS],
    lat_sum_ns: AtomicU64,
    lat_count: AtomicU64,
}

impl Metrics {
    /// All-zero metrics with a single ingress gauge (the classic
    /// single-leader shape).
    pub fn new() -> Self {
        Self::with_shards(1)
    }

    /// All-zero metrics with one ingress queue depth gauge, one phase
    /// histogram triple and one shed-reason counter pair per shard.
    pub fn with_shards(shards: usize) -> Self {
        let n = shards.max(1);
        Metrics {
            ingress_depth: (0..n).map(|_| AtomicU64::new(0)).collect(),
            shed_deadline: (0..n).map(|_| AtomicU64::new(0)).collect(),
            shed_queue_full: (0..n).map(|_| AtomicU64::new(0)).collect(),
            phase_hists: (0..n).map(|_| <[PhaseHist; 3]>::default()).collect(),
            ..Metrics::default()
        }
    }

    /// Number of ingress gauges (== the coordinator's shard count).
    pub fn shards(&self) -> usize {
        self.ingress_depth.len()
    }

    /// Count one submitted request of `elements` operand lanes.
    pub fn record_request(&self, elements: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elements as u64, Ordering::Relaxed);
    }

    /// Count one dispatched batch (`used` live lanes of `capacity`).
    pub fn record_batch(&self, used: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_elements.fetch_add((capacity - used) as u64, Ordering::Relaxed);
    }

    /// Record one span's submit-to-reply latency.
    pub fn record_latency(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
        self.hist[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request's time in `phase` on `shard` (out-of-range
    /// shards clamp to the last, so reason/phase sums stay exact).
    pub fn record_phase(&self, phase: ServePhase, shard: usize, d: Duration) {
        if let Some(h) = self.phase_hists.get(shard).or(self.phase_hists.last()) {
            h[phase.index()].record(d.as_nanos() as u64);
        }
    }

    fn bump_shard(counters: &[AtomicU64], shard: usize) {
        if let Some(c) = counters.get(shard).or(counters.last()) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one backpressure rejection on `shard` (`reason="queue_full"`;
    /// out-of-range shards clamp to the last so the per-reason sum always
    /// equals the aggregate).
    pub fn record_rejected(&self, shard: usize) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        Self::bump_shard(&self.shed_queue_full, shard);
    }

    /// Count one deadline-shed request on `shard` (admission control said
    /// no; `reason="deadline"`, same clamping as [`Self::record_rejected`]).
    pub fn record_shed(&self, shard: usize) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        Self::bump_shard(&self.shed_deadline, shard);
    }

    /// A request entered shard `s`'s ingress queue.
    pub fn ingress_enqueued(&self, s: usize) {
        if let Some(g) = self.ingress_depth.get(s) {
            g.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A request left shard `s`'s ingress queue (picked up for batching).
    pub fn ingress_dequeued(&self, s: usize) {
        if let Some(g) = self.ingress_depth.get(s) {
            // saturating: a racing reader must never observe a wrapped
            // gauge; enqueue/dequeue pairing keeps this exact in practice
            let _ = g.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
        }
    }

    /// Current ingress queue depth of shard `s` (0 for unknown shards).
    pub fn ingress_depth(&self, s: usize) -> u64 {
        self.ingress_depth.get(s).map_or(0, |g| g.load(Ordering::Relaxed))
    }

    /// A batch entered a dispatch channel.
    pub fn batch_enqueued(&self) {
        self.batch_queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker picked a batch out of a dispatch channel.
    pub fn batch_dequeued(&self) {
        let _ = self.batch_queue_depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(1))
        });
    }

    /// Batches currently awaiting a worker across all dispatch channels.
    pub fn batch_queue_depth(&self) -> u64 {
        self.batch_queue_depth.load(Ordering::Relaxed)
    }

    /// Fold one batch execution time into the service-time EWMA
    /// (`new = (3·old + sample) / 4`; the first sample seeds it).
    pub fn record_batch_service(&self, d: Duration) {
        let ns = (d.as_nanos() as u64).max(1);
        let _ = self.batch_service_ewma_ns.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |old| Some(if old == 0 { ns } else { (3 * old + ns) / 4 }),
        );
    }

    /// EWMA batch service time in ns (0 before the first batch).
    pub fn batch_service_ewma_ns(&self) -> u64 {
        self.batch_service_ewma_ns.load(Ordering::Relaxed)
    }

    /// Set the served-rung gauge (the coordinator's `set_rung` mirrors
    /// its rung register here).
    pub fn set_governor_rung(&self, rung: u64) {
        self.governor_rung.store(rung, Ordering::Relaxed);
    }

    /// Accuracy-ladder rung currently being served.
    pub fn governor_rung(&self) -> u64 {
        self.governor_rung.load(Ordering::Relaxed)
    }

    /// Count one committed governor switch.
    pub fn record_governor_switch(&self) {
        self.governor_switches.fetch_add(1, Ordering::Relaxed);
    }

    /// Rung switches the governor has committed.
    pub fn governor_switches(&self) -> u64 {
        self.governor_switches.load(Ordering::Relaxed)
    }

    /// Close one governor decision window with its QoR observation
    /// (bumps the window counter and sets the last-window QoR gauge).
    pub fn record_governor_window(&self, qor: f64) {
        self.governor_windows.fetch_add(1, Ordering::Relaxed);
        self.governor_window_qor_bits.store(qor.to_bits(), Ordering::Relaxed);
    }

    /// Decision windows the governor has closed.
    pub fn governor_windows(&self) -> u64 {
        self.governor_windows.load(Ordering::Relaxed)
    }

    /// Last closed window's QoR observation (0.0 before the first window).
    pub fn governor_window_qor(&self) -> f64 {
        f64::from_bits(self.governor_window_qor_bits.load(Ordering::Relaxed))
    }

    /// Approximate latency percentile from the log2 histogram.
    ///
    /// Contract (pinned by `latency_percentile_pins_edge_cases`):
    ///
    /// * **Empty histogram → 0.** Before any reply every percentile reads
    ///   0, never a phantom bucket bound.
    /// * **Bucket-upper-bound quantization.** The return value is the
    ///   *upper* bound `2^(i+11)` of the first bucket whose cumulative
    ///   count reaches `ceil(total·p)`; bucket `i` holds
    ///   `[2^(10+i), 2^(11+i))` ns. A sample is therefore reported at up
    ///   to 2× its true value (e.g. both 2048ns and 4095ns read 4096),
    ///   and sub-µs samples clamp into bucket 0 and read 2048.
    /// * **Monotone in `p`** — cumulative counts only grow.
    /// * The last bucket is unbounded above, so its reported "upper
    ///   bound" `2^34` ns (~17s) is a floor, not a bound, for samples
    ///   ≥ `2^33` ns.
    pub fn latency_percentile_ns(&self, p: f64) -> u64 {
        hist_percentile_ns(&self.snapshot_hist(&self.hist), p)
    }

    fn snapshot_hist(&self, hist: &[AtomicU64; BUCKETS]) -> [u64; BUCKETS] {
        let mut out = [0u64; BUCKETS];
        for (o, h) in out.iter_mut().zip(hist.iter()) {
            *o = h.load(Ordering::Relaxed);
        }
        out
    }

    /// `phase` latency percentile, merged across shards (same
    /// quantization contract as [`Self::latency_percentile_ns`]).
    pub fn phase_percentile_ns(&self, phase: ServePhase, p: f64) -> u64 {
        let mut merged = [0u64; BUCKETS];
        for shard in &self.phase_hists {
            let snap = self.snapshot_hist(&shard[phase.index()].hist);
            for (m, v) in merged.iter_mut().zip(snap.iter()) {
                *m += v;
            }
        }
        hist_percentile_ns(&merged, p)
    }

    /// Cross-shard p50/p99 of every serving phase in one snapshot.
    pub fn phase_breakdown(&self) -> PhaseBreakdown {
        PhaseBreakdown {
            queue_p50_ns: self.phase_percentile_ns(ServePhase::Queue, 0.5),
            queue_p99_ns: self.phase_percentile_ns(ServePhase::Queue, 0.99),
            batch_form_p50_ns: self.phase_percentile_ns(ServePhase::BatchForm, 0.5),
            batch_form_p99_ns: self.phase_percentile_ns(ServePhase::BatchForm, 0.99),
            execute_p50_ns: self.phase_percentile_ns(ServePhase::Execute, 0.5),
            execute_p99_ns: self.phase_percentile_ns(ServePhase::Execute, 0.99),
        }
    }

    /// Median span latency in ns (histogram upper bound).
    pub fn p50_ns(&self) -> u64 {
        self.latency_percentile_ns(0.5)
    }

    /// 99th-percentile span latency in ns (histogram upper bound).
    pub fn p99_ns(&self) -> u64 {
        self.latency_percentile_ns(0.99)
    }

    /// 99.9th-percentile span latency in ns (histogram upper bound) —
    /// the tail the open-loop load bench tracks per rate rung.
    pub fn p999_ns(&self) -> u64 {
        self.latency_percentile_ns(0.999)
    }

    /// Mean span latency in ns (0 before any reply).
    pub fn mean_latency_ns(&self) -> f64 {
        let n = self.lat_count.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.lat_sum_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// One-line human-readable dump of every counter.
    pub fn summary(&self) -> String {
        format!(
            "requests={} elements={} batches={} padding={} rejected={} shed={} \
             mean_lat={:.1}µs p50={:.1}µs p99={:.1}µs p999={:.1}µs",
            self.requests.load(Ordering::Relaxed),
            self.elements.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.padded_elements.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.mean_latency_ns() / 1e3,
            self.p50_ns() as f64 / 1e3,
            self.p99_ns() as f64 / 1e3,
            self.p999_ns() as f64 / 1e3,
        )
    }

    /// Prometheus text-exposition dump of every counter, gauge and the
    /// latency summary — what a `/metrics` endpoint would serve, printed
    /// by `rapid serve` / `rapid serve-bench` after a run.
    pub fn metrics_text(&self) -> String {
        let mut s = String::new();
        let counter = |s: &mut String, name: &str, help: &str, v: u64| {
            s.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        counter(&mut s, "rapid_requests_total", "Requests submitted.", self.requests.load(Ordering::Relaxed));
        counter(&mut s, "rapid_elements_total", "Operand elements submitted.", self.elements.load(Ordering::Relaxed));
        counter(&mut s, "rapid_batches_total", "Batches dispatched to workers.", self.batches.load(Ordering::Relaxed));
        counter(&mut s, "rapid_padded_elements_total", "Zero-padding elements in short batches.", self.padded_elements.load(Ordering::Relaxed));
        counter(&mut s, "rapid_rejected_total", "Requests rejected by backpressure.", self.rejected.load(Ordering::Relaxed));
        counter(&mut s, "rapid_shed_total", "Requests shed by deadline admission control.", self.shed.load(Ordering::Relaxed));
        s.push_str("# HELP rapid_shed_reason_total Requests refused at admission, by reason and shard (deadline sheds + queue_full backpressure).\n");
        s.push_str("# TYPE rapid_shed_reason_total counter\n");
        for (reason, counters) in [("deadline", &self.shed_deadline), ("queue_full", &self.shed_queue_full)] {
            for (i, c) in counters.iter().enumerate() {
                s.push_str(&format!(
                    "rapid_shed_reason_total{{reason=\"{reason}\",shard=\"{i}\"}} {}\n",
                    c.load(Ordering::Relaxed)
                ));
            }
        }
        s.push_str("# HELP rapid_ingress_queue_depth Requests waiting in a shard's ingress queue.\n");
        s.push_str("# TYPE rapid_ingress_queue_depth gauge\n");
        for (i, g) in self.ingress_depth.iter().enumerate() {
            s.push_str(&format!(
                "rapid_ingress_queue_depth{{shard=\"{i}\"}} {}\n",
                g.load(Ordering::Relaxed)
            ));
        }
        s.push_str("# HELP rapid_batch_queue_depth Batches awaiting a worker in dispatch channels.\n");
        s.push_str("# TYPE rapid_batch_queue_depth gauge\n");
        s.push_str(&format!("rapid_batch_queue_depth {}\n", self.batch_queue_depth()));
        s.push_str("# HELP rapid_batch_service_ewma_ns EWMA batch execution time (ns).\n");
        s.push_str("# TYPE rapid_batch_service_ewma_ns gauge\n");
        s.push_str(&format!("rapid_batch_service_ewma_ns {}\n", self.batch_service_ewma_ns()));
        counter(
            &mut s,
            "rapid_governor_switches_total",
            "Accuracy-rung switches committed by the QoR governor.",
            self.governor_switches(),
        );
        counter(
            &mut s,
            "rapid_governor_windows_total",
            "Decision windows closed by the QoR governor.",
            self.governor_windows(),
        );
        s.push_str("# HELP rapid_governor_rung Accuracy-ladder rung currently served (0 = cheapest).\n");
        s.push_str("# TYPE rapid_governor_rung gauge\n");
        s.push_str(&format!("rapid_governor_rung {}\n", self.governor_rung()));
        s.push_str("# HELP rapid_governor_window_qor Last decision window's QoR observation (higher is better).\n");
        s.push_str("# TYPE rapid_governor_window_qor gauge\n");
        s.push_str(&format!("rapid_governor_window_qor {}\n", prom_f64(self.governor_window_qor())));
        s.push_str("# HELP rapid_phase_ns Per-phase request latency (ns): ingress queue wait, batch formation, execution.\n");
        s.push_str("# TYPE rapid_phase_ns histogram\n");
        for phase in ServePhase::ALL {
            for (i, shard) in self.phase_hists.iter().enumerate() {
                let h = &shard[phase.index()];
                let labels = format!("phase=\"{}\",shard=\"{i}\"", phase.label());
                let mut acc = 0u64;
                // finite `le` bounds stop one short of the last bucket:
                // it is unbounded above, so it folds into +Inf
                for (b, c) in h.hist.iter().enumerate().take(BUCKETS - 1) {
                    acc += c.load(Ordering::Relaxed);
                    s.push_str(&format!(
                        "rapid_phase_ns_bucket{{{labels},le=\"{}\"}} {acc}\n",
                        bucket_upper_ns(b)
                    ));
                }
                let count = h.count.load(Ordering::Relaxed);
                s.push_str(&format!("rapid_phase_ns_bucket{{{labels},le=\"+Inf\"}} {count}\n"));
                s.push_str(&format!("rapid_phase_ns_sum{{{labels}}} {}\n", h.sum_ns.load(Ordering::Relaxed)));
                s.push_str(&format!("rapid_phase_ns_count{{{labels}}} {count}\n"));
            }
        }
        s.push_str("# HELP rapid_latency_ns Span submit-to-reply latency (ns).\n");
        s.push_str("# TYPE rapid_latency_ns summary\n");
        s.push_str(&format!("rapid_latency_ns{{quantile=\"0.5\"}} {}\n", self.p50_ns()));
        s.push_str(&format!("rapid_latency_ns{{quantile=\"0.99\"}} {}\n", self.p99_ns()));
        s.push_str(&format!("rapid_latency_ns{{quantile=\"0.999\"}} {}\n", self.p999_ns()));
        s.push_str(&format!("rapid_latency_ns_sum {}\n", self.lat_sum_ns.load(Ordering::Relaxed)));
        s.push_str(&format!("rapid_latency_ns_count {}\n", self.lat_count.load(Ordering::Relaxed)));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100);
        m.record_request(28);
        m.record_batch(100, 128);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.elements.load(Ordering::Relaxed), 128);
        assert_eq!(m.padded_elements.load(Ordering::Relaxed), 28);
    }

    #[test]
    fn percentile_monotone() {
        let m = Metrics::new();
        for us in [5u64, 10, 20, 40, 80, 160, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert!(m.p50_ns() <= m.p99_ns());
        assert!(m.p99_ns() <= m.p999_ns());
        assert!(m.mean_latency_ns() > 0.0);
    }

    #[test]
    fn gauges_track_depth_and_saturate() {
        let m = Metrics::with_shards(3);
        assert_eq!(m.shards(), 3);
        m.ingress_enqueued(1);
        m.ingress_enqueued(1);
        m.ingress_dequeued(1);
        assert_eq!(m.ingress_depth(1), 1);
        assert_eq!(m.ingress_depth(0), 0);
        // dequeue on an empty gauge saturates at zero, never wraps
        m.ingress_dequeued(0);
        assert_eq!(m.ingress_depth(0), 0);
        // out-of-range shards are inert
        m.ingress_enqueued(9);
        assert_eq!(m.ingress_depth(9), 0);
        m.batch_enqueued();
        m.batch_enqueued();
        m.batch_dequeued();
        assert_eq!(m.batch_queue_depth(), 1);
        m.batch_dequeued();
        m.batch_dequeued();
        assert_eq!(m.batch_queue_depth(), 0);
    }

    #[test]
    fn service_ewma_seeds_then_smooths() {
        let m = Metrics::new();
        assert_eq!(m.batch_service_ewma_ns(), 0);
        m.record_batch_service(Duration::from_nanos(1000));
        assert_eq!(m.batch_service_ewma_ns(), 1000);
        m.record_batch_service(Duration::from_nanos(2000));
        // (3*1000 + 2000) / 4 = 1250
        assert_eq!(m.batch_service_ewma_ns(), 1250);
    }

    #[test]
    fn governor_gauges_roundtrip() {
        let m = Metrics::new();
        assert_eq!(m.governor_rung(), 0);
        assert_eq!(m.governor_switches(), 0);
        assert_eq!(m.governor_window_qor(), 0.0);
        m.set_governor_rung(3);
        m.record_governor_switch();
        m.record_governor_window(41.25);
        m.record_governor_window(f64::INFINITY);
        assert_eq!(m.governor_rung(), 3);
        assert_eq!(m.governor_switches(), 1);
        assert_eq!(m.governor_windows(), 2);
        assert!(m.governor_window_qor().is_infinite());
        let t = m.metrics_text();
        assert!(t.contains("rapid_governor_rung 3"), "{t}");
        assert!(t.contains("rapid_governor_switches_total 1"), "{t}");
        assert!(t.contains("rapid_governor_windows_total 2"), "{t}");
        assert!(t.contains("rapid_governor_window_qor +Inf"), "{t}");
        assert!(t.contains("# TYPE rapid_governor_rung gauge"), "{t}");
        assert!(t.contains("# TYPE rapid_governor_switches_total counter"), "{t}");
    }

    #[test]
    fn metrics_text_is_prometheus_shaped() {
        let m = Metrics::with_shards(2);
        m.record_request(10);
        m.record_shed(0);
        m.ingress_enqueued(1);
        m.record_latency(Duration::from_micros(50));
        let t = m.metrics_text();
        assert!(t.contains("# TYPE rapid_requests_total counter"), "{t}");
        assert!(t.contains("rapid_requests_total 1"), "{t}");
        assert!(t.contains("rapid_shed_total 1"), "{t}");
        assert!(t.contains("rapid_ingress_queue_depth{shard=\"0\"} 0"), "{t}");
        assert!(t.contains("rapid_ingress_queue_depth{shard=\"1\"} 1"), "{t}");
        assert!(t.contains("rapid_latency_ns{quantile=\"0.999\"}"), "{t}");
        assert!(t.contains("rapid_latency_ns_count 1"), "{t}");
        // every non-comment line is "name[{labels}] value"
        for line in t.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn latency_percentile_pins_edge_cases() {
        // empty histogram: every percentile is 0, not a bucket bound
        let m = Metrics::new();
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(m.latency_percentile_ns(p), 0);
        }
        // sub-µs samples clamp into bucket 0 and read its upper bound
        m.record_latency(Duration::from_nanos(1));
        assert_eq!(m.latency_percentile_ns(1.0), 2048);
        // a sample exactly on a bucket's lower bound reads the upper
        // bound of that bucket: 2048 lands in [2048, 4096) → 4096
        let m = Metrics::new();
        m.record_latency(Duration::from_nanos(2048));
        assert_eq!(m.latency_percentile_ns(0.5), 4096);
        // ... as does the value just below the upper bound
        let m = Metrics::new();
        m.record_latency(Duration::from_nanos(4095));
        assert_eq!(m.latency_percentile_ns(0.5), 4096);
        // the last bucket is unbounded above; anything ≥ 2^33 reads 2^34
        let m = Metrics::new();
        m.record_latency(Duration::from_nanos(1 << 33));
        m.record_latency(Duration::from_secs(3600));
        assert_eq!(m.latency_percentile_ns(1.0), 1 << 34);
    }

    #[test]
    fn shed_reasons_reconcile_with_aggregates() {
        let m = Metrics::with_shards(2);
        m.record_shed(0);
        m.record_shed(0);
        m.record_shed(1);
        m.record_rejected(1);
        // out-of-range shard clamps to the last, keeping sums exact
        m.record_rejected(7);
        assert_eq!(m.shed.load(Ordering::Relaxed), 3);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 2);
        let t = m.metrics_text();
        assert!(t.contains("# TYPE rapid_shed_reason_total counter"), "{t}");
        assert!(t.contains("rapid_shed_reason_total{reason=\"deadline\",shard=\"0\"} 2"), "{t}");
        assert!(t.contains("rapid_shed_reason_total{reason=\"deadline\",shard=\"1\"} 1"), "{t}");
        assert!(t.contains("rapid_shed_reason_total{reason=\"queue_full\",shard=\"0\"} 0"), "{t}");
        assert!(t.contains("rapid_shed_reason_total{reason=\"queue_full\",shard=\"1\"} 2"), "{t}");
    }

    #[test]
    fn phase_histogram_merges_shards_and_exposes_buckets() {
        let m = Metrics::with_shards(2);
        m.record_phase(ServePhase::Queue, 0, Duration::from_nanos(1500));
        m.record_phase(ServePhase::Queue, 1, Duration::from_nanos(3000));
        m.record_phase(ServePhase::Execute, 0, Duration::from_micros(100));
        assert_eq!(m.phase_percentile_ns(ServePhase::Queue, 0.5), 2048);
        assert_eq!(m.phase_percentile_ns(ServePhase::Queue, 1.0), 4096);
        assert_eq!(m.phase_percentile_ns(ServePhase::BatchForm, 0.99), 0);
        let b = m.phase_breakdown();
        assert_eq!(b.queue_p50_ns, 2048);
        assert_eq!(b.queue_p99_ns, 4096);
        assert_eq!(b.batch_form_p99_ns, 0);
        assert_eq!(b.execute_p50_ns, m.phase_percentile_ns(ServePhase::Execute, 0.5));
        let t = m.metrics_text();
        assert!(t.contains("# TYPE rapid_phase_ns histogram"), "{t}");
        assert!(t.contains("rapid_phase_ns_bucket{phase=\"queue\",shard=\"0\",le=\"2048\"} 1"), "{t}");
        assert!(t.contains("rapid_phase_ns_bucket{phase=\"queue\",shard=\"1\",le=\"4096\"} 1"), "{t}");
        assert!(t.contains("rapid_phase_ns_bucket{phase=\"queue\",shard=\"0\",le=\"+Inf\"} 1"), "{t}");
        assert!(t.contains("rapid_phase_ns_sum{phase=\"queue\",shard=\"0\"} 1500"), "{t}");
        assert!(t.contains("rapid_phase_ns_count{phase=\"execute\",shard=\"0\"} 1"), "{t}");
        assert!(t.contains("rapid_phase_ns_count{phase=\"batch_form\",shard=\"1\"} 0"), "{t}");
    }
}
