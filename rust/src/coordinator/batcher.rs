//! Dynamic batcher: packs operand pairs into fixed-shape batches (the AOT
//! artifact's compiled batch size), flushing on size or deadline — the
//! same policy a serving router uses to feed a fixed-shape accelerator
//! kernel. Short batches are padded with zero operands (the kernels map
//! zero inputs to zero outputs, so padding is inert) and trimmed on reply.

use std::time::{Duration, Instant};

/// One packed batch plus bookkeeping to route results back.
#[derive(Debug)]
pub struct Batch {
    /// First operand lanes, padded to the batch capacity.
    pub a: Vec<i64>,
    /// Second operand lanes, padded to the batch capacity.
    pub b: Vec<i64>,
    /// (request id, offset in batch, length, offset within the request) —
    /// the last field reassembles split requests regardless of the order
    /// their batches complete in.
    pub spans: Vec<(u64, usize, usize, usize)>,
    /// live elements before padding
    pub used: usize,
}

/// Accumulates requests into fixed-size batches.
pub struct DynamicBatcher {
    capacity: usize,
    max_wait: Duration,
    cur_a: Vec<i64>,
    cur_b: Vec<i64>,
    spans: Vec<(u64, usize, usize, usize)>,
    opened_at: Option<Instant>,
}

impl DynamicBatcher {
    /// Batcher producing `capacity`-lane batches, flushing open batches
    /// after `max_wait`.
    pub fn new(capacity: usize, max_wait: Duration) -> Self {
        DynamicBatcher {
            capacity,
            max_wait,
            cur_a: Vec::with_capacity(capacity),
            cur_b: Vec::with_capacity(capacity),
            spans: Vec::new(),
            opened_at: None,
        }
    }

    /// Lanes waiting in the open (unflushed) batch.
    pub fn pending(&self) -> usize {
        self.cur_a.len()
    }

    /// Offer a request; returns any batches that became full. A request
    /// larger than the capacity is split across batches. Allocates the
    /// result vector per call — hot loops use [`Self::offer_into`].
    pub fn offer(&mut self, id: u64, a: &[i64], b: &[i64]) -> Vec<Batch> {
        let mut out = Vec::new();
        self.offer_into(id, a, b, &mut out);
        out
    }

    /// Allocation-free variant of [`Self::offer`]: full batches are
    /// appended to `out` (which is not cleared, so a caller-owned reusable
    /// buffer makes steady-state batch formation allocation-free — the
    /// routing loops drain and reuse one buffer across all offers).
    pub fn offer_into(&mut self, id: u64, a: &[i64], b: &[i64], out: &mut Vec<Batch>) {
        assert_eq!(a.len(), b.len());
        let mut off = 0;
        while off < a.len() {
            if self.opened_at.is_none() {
                self.opened_at = Some(Instant::now());
            }
            let room = self.capacity - self.cur_a.len();
            let take = room.min(a.len() - off);
            let start = self.cur_a.len();
            self.cur_a.extend_from_slice(&a[off..off + take]);
            self.cur_b.extend_from_slice(&b[off..off + take]);
            self.spans.push((id, start, take, off));
            off += take;
            if self.cur_a.len() == self.capacity {
                out.push(self.flush().expect("full batch flushes"));
            }
        }
    }

    /// Flush the open batch (padding to capacity), if any.
    pub fn flush(&mut self) -> Option<Batch> {
        if self.cur_a.is_empty() {
            self.opened_at = None;
            return None;
        }
        let used = self.cur_a.len();
        let mut a = std::mem::replace(&mut self.cur_a, Vec::with_capacity(self.capacity));
        let mut b = std::mem::replace(&mut self.cur_b, Vec::with_capacity(self.capacity));
        a.resize(self.capacity, 0);
        b.resize(self.capacity, 0);
        let spans = std::mem::take(&mut self.spans);
        self.opened_at = None;
        Some(Batch { a, b, spans, used })
    }

    /// True when the open batch has waited past the deadline.
    pub fn deadline_expired(&self) -> bool {
        match self.opened_at {
            Some(t) => t.elapsed() >= self.max_wait,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> DynamicBatcher {
        DynamicBatcher::new(8, Duration::from_millis(1))
    }

    #[test]
    fn accumulates_until_full() {
        let mut b = mk();
        assert!(b.offer(1, &[1, 2, 3], &[4, 5, 6]).is_empty());
        assert_eq!(b.pending(), 3);
        let full = b.offer(2, &[1, 2, 3, 4, 5], &[1, 2, 3, 4, 5]);
        assert_eq!(full.len(), 1);
        assert_eq!(full[0].used, 8);
        assert_eq!(full[0].spans, vec![(1, 0, 3, 0), (2, 3, 5, 0)]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn splits_oversized_requests() {
        let mut b = mk();
        let a: Vec<i64> = (0..20).collect();
        let batches = b.offer(7, &a, &a);
        assert_eq!(batches.len(), 2, "two full batches emitted");
        assert_eq!(b.pending(), 4, "tail kept open");
        let tail = b.flush().unwrap();
        assert_eq!(tail.used, 4);
        assert_eq!(tail.a.len(), 8, "padded to capacity");
        assert_eq!(&tail.a[..4], &[16, 17, 18, 19]);
        assert_eq!(&tail.a[4..], &[0, 0, 0, 0]);
    }

    #[test]
    fn offer_into_appends_without_clearing() {
        // the reusable-buffer contract: offer_into never clears `out`,
        // and produces exactly the batches offer would
        let mut b1 = mk();
        let mut b2 = mk();
        let a: Vec<i64> = (0..20).collect();
        let via_offer = b1.offer(3, &a, &a);
        let mut out = Vec::new();
        b2.offer_into(3, &a, &a, &mut out);
        assert_eq!(out.len(), via_offer.len());
        for (x, y) in out.iter().zip(&via_offer) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.b, y.b);
            assert_eq!(x.spans, y.spans);
            assert_eq!(x.used, y.used);
        }
        // appending: a second offer_into adds to the same buffer
        let n0 = out.len();
        let big: Vec<i64> = (0..16).collect();
        b2.flush();
        b2.offer_into(4, &big, &big, &mut out);
        assert!(out.len() > n0, "second offer appended");
        assert_eq!(out[n0].spans[0].0, 4);
    }

    #[test]
    fn flush_empty_is_none() {
        let mut b = mk();
        assert!(b.flush().is_none());
    }

    #[test]
    fn spans_cover_batch_exactly() {
        // property: spans partition [0, used)
        let mut b = DynamicBatcher::new(16, Duration::from_millis(1));
        let mut rng = crate::util::XorShift256::new(13);
        let mut batches = Vec::new();
        for id in 0..50u64 {
            let len = 1 + rng.below(9) as usize;
            let v: Vec<i64> = (0..len as i64).collect();
            batches.extend(b.offer(id, &v, &v));
        }
        batches.extend(b.flush());
        for batch in batches {
            let mut covered = 0;
            for (_, off, len, _) in &batch.spans {
                assert_eq!(*off, covered, "spans must be contiguous");
                covered += len;
            }
            assert_eq!(covered, batch.used);
        }
    }
}
