//! Dynamic batcher: packs operand pairs into fixed-shape batches (the AOT
//! artifact's compiled batch size), flushing on size or deadline — the
//! same policy a serving router uses to feed a fixed-shape accelerator
//! kernel. Short batches are padded with zero operands (the kernels map
//! zero inputs to zero outputs, so padding is inert) and trimmed on reply.
//!
//! Batches are additionally keyed by a *rung* — the accuracy-ladder index
//! the QoR governor ([`crate::coordinator::governor`]) stamps on every
//! request. A batch only ever holds lanes of one rung: offering a request
//! whose rung differs from the open batch's flushes the open batch first,
//! so a served batch maps to exactly one unit configuration and replies
//! stay bit-identical regardless of when a switch lands relative to batch
//! formation. With the governor off every request carries rung 0 and the
//! policy is inert — batch boundaries are byte-identical to the
//! pre-governor batcher.

use std::time::{Duration, Instant};

/// One packed batch plus bookkeeping to route results back.
#[derive(Debug)]
pub struct Batch {
    /// First operand lanes, padded to the batch capacity.
    pub a: Vec<i64>,
    /// Second operand lanes, padded to the batch capacity.
    pub b: Vec<i64>,
    /// (request id, offset in batch, length, offset within the request) —
    /// the last field reassembles split requests regardless of the order
    /// their batches complete in.
    pub spans: Vec<(u64, usize, usize, usize)>,
    /// live elements before padding
    pub used: usize,
    /// Accuracy-ladder rung every lane of this batch is served at
    /// (0 when no governor is attached).
    pub rung: u32,
    /// When the first lane entered this batch — the start of its
    /// formation window (trace spans + batch-form latency attribution).
    pub opened_at: Instant,
}

/// Accumulates requests into fixed-size batches.
pub struct DynamicBatcher {
    capacity: usize,
    max_wait: Duration,
    cur_a: Vec<i64>,
    cur_b: Vec<i64>,
    spans: Vec<(u64, usize, usize, usize)>,
    opened_at: Option<Instant>,
    /// rung of the open batch (meaningful only while lanes are pending)
    cur_rung: u32,
}

impl DynamicBatcher {
    /// Batcher producing `capacity`-lane batches, flushing open batches
    /// after `max_wait`.
    pub fn new(capacity: usize, max_wait: Duration) -> Self {
        DynamicBatcher {
            capacity,
            max_wait,
            cur_a: Vec::with_capacity(capacity),
            cur_b: Vec::with_capacity(capacity),
            spans: Vec::new(),
            opened_at: None,
            cur_rung: 0,
        }
    }

    /// Lanes waiting in the open (unflushed) batch.
    pub fn pending(&self) -> usize {
        self.cur_a.len()
    }

    /// Offer a rung-0 request; returns any batches that became full. A
    /// request larger than the capacity is split across batches. Allocates
    /// the result vector per call — hot loops use [`Self::offer_into`].
    pub fn offer(&mut self, id: u64, a: &[i64], b: &[i64]) -> Vec<Batch> {
        let mut out = Vec::new();
        self.offer_into(id, 0, a, b, &mut out);
        out
    }

    /// Allocation-free variant of [`Self::offer`]: full batches are
    /// appended to `out` (which is not cleared, so a caller-owned reusable
    /// buffer makes steady-state batch formation allocation-free — the
    /// routing loops drain and reuse one buffer across all offers).
    ///
    /// `rung` keys the batch: when the open batch holds lanes of a
    /// different rung it is flushed (short, padded) before this request's
    /// lanes start a new one — a batch never mixes rungs.
    pub fn offer_into(&mut self, id: u64, rung: u32, a: &[i64], b: &[i64], out: &mut Vec<Batch>) {
        assert_eq!(a.len(), b.len());
        if !self.cur_a.is_empty() && self.cur_rung != rung {
            out.push(self.flush().expect("non-empty batch flushes"));
        }
        self.cur_rung = rung;
        let mut off = 0;
        while off < a.len() {
            if self.opened_at.is_none() {
                self.opened_at = Some(Instant::now());
            }
            let room = self.capacity - self.cur_a.len();
            let take = room.min(a.len() - off);
            let start = self.cur_a.len();
            self.cur_a.extend_from_slice(&a[off..off + take]);
            self.cur_b.extend_from_slice(&b[off..off + take]);
            self.spans.push((id, start, take, off));
            off += take;
            if self.cur_a.len() == self.capacity {
                out.push(self.flush().expect("full batch flushes"));
            }
        }
    }

    /// Flush the open batch (padding to capacity), if any.
    pub fn flush(&mut self) -> Option<Batch> {
        if self.cur_a.is_empty() {
            self.opened_at = None;
            return None;
        }
        let used = self.cur_a.len();
        let mut a = std::mem::replace(&mut self.cur_a, Vec::with_capacity(self.capacity));
        let mut b = std::mem::replace(&mut self.cur_b, Vec::with_capacity(self.capacity));
        a.resize(self.capacity, 0);
        b.resize(self.capacity, 0);
        let spans = std::mem::take(&mut self.spans);
        let opened_at = self.opened_at.take().unwrap_or_else(Instant::now);
        Some(Batch { a, b, spans, used, rung: self.cur_rung, opened_at })
    }

    /// True when the open batch has waited past the deadline.
    pub fn deadline_expired(&self) -> bool {
        match self.opened_at {
            Some(t) => t.elapsed() >= self.max_wait,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> DynamicBatcher {
        DynamicBatcher::new(8, Duration::from_millis(1))
    }

    #[test]
    fn accumulates_until_full() {
        let mut b = mk();
        assert!(b.offer(1, &[1, 2, 3], &[4, 5, 6]).is_empty());
        assert_eq!(b.pending(), 3);
        let full = b.offer(2, &[1, 2, 3, 4, 5], &[1, 2, 3, 4, 5]);
        assert_eq!(full.len(), 1);
        assert_eq!(full[0].used, 8);
        assert_eq!(full[0].spans, vec![(1, 0, 3, 0), (2, 3, 5, 0)]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn splits_oversized_requests() {
        let mut b = mk();
        let a: Vec<i64> = (0..20).collect();
        let batches = b.offer(7, &a, &a);
        assert_eq!(batches.len(), 2, "two full batches emitted");
        assert_eq!(b.pending(), 4, "tail kept open");
        let tail = b.flush().unwrap();
        assert_eq!(tail.used, 4);
        assert_eq!(tail.a.len(), 8, "padded to capacity");
        assert_eq!(&tail.a[..4], &[16, 17, 18, 19]);
        assert_eq!(&tail.a[4..], &[0, 0, 0, 0]);
    }

    #[test]
    fn offer_into_appends_without_clearing() {
        // the reusable-buffer contract: offer_into never clears `out`,
        // and produces exactly the batches offer would
        let mut b1 = mk();
        let mut b2 = mk();
        let a: Vec<i64> = (0..20).collect();
        let via_offer = b1.offer(3, &a, &a);
        let mut out = Vec::new();
        b2.offer_into(3, 0, &a, &a, &mut out);
        assert_eq!(out.len(), via_offer.len());
        for (x, y) in out.iter().zip(&via_offer) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.b, y.b);
            assert_eq!(x.spans, y.spans);
            assert_eq!(x.used, y.used);
        }
        // appending: a second offer_into adds to the same buffer
        let n0 = out.len();
        let big: Vec<i64> = (0..16).collect();
        b2.flush();
        b2.offer_into(4, 0, &big, &big, &mut out);
        assert!(out.len() > n0, "second offer appended");
        assert_eq!(out[n0].spans[0].0, 4);
    }

    #[test]
    fn rung_change_flushes_open_batch() {
        // a batch never mixes rungs: offering under a new rung closes the
        // open (short, padded) batch first
        let mut b = mk();
        let mut out = Vec::new();
        b.offer_into(1, 2, &[1, 2, 3], &[4, 5, 6], &mut out);
        assert!(out.is_empty(), "short batch stays open under one rung");
        b.offer_into(2, 3, &[7], &[8], &mut out);
        assert_eq!(out.len(), 1, "rung change forced a flush");
        assert_eq!(out[0].rung, 2);
        assert_eq!(out[0].used, 3);
        assert_eq!(b.pending(), 1, "new-rung lanes open a fresh batch");
        let tail = b.flush().unwrap();
        assert_eq!(tail.rung, 3);
        assert_eq!(tail.used, 1);
    }

    #[test]
    fn constant_rung_is_byte_identical_to_rungless_offers() {
        // the governor-off pin at batcher level: a stream offered entirely
        // at rung 0 produces exactly the batches the rungless `offer` API
        // produces — same boundaries, same lanes, same spans
        let mut plain = DynamicBatcher::new(16, Duration::from_millis(1));
        let mut tagged = DynamicBatcher::new(16, Duration::from_millis(1));
        let mut rng = crate::util::XorShift256::new(5);
        let mut got_plain = Vec::new();
        let mut got_tagged = Vec::new();
        for id in 0..40u64 {
            let len = 1 + rng.below(22) as usize;
            let v: Vec<i64> = (0..len as i64).map(|x| x + id as i64).collect();
            got_plain.extend(plain.offer(id, &v, &v));
            tagged.offer_into(id, 0, &v, &v, &mut got_tagged);
        }
        got_plain.extend(plain.flush());
        got_tagged.extend(tagged.flush());
        assert_eq!(got_plain.len(), got_tagged.len());
        for (x, y) in got_plain.iter().zip(&got_tagged) {
            assert_eq!(x.a, y.a);
            assert_eq!(x.b, y.b);
            assert_eq!(x.spans, y.spans);
            assert_eq!(x.used, y.used);
            assert_eq!(x.rung, y.rung);
        }
    }

    #[test]
    fn flush_empty_is_none() {
        let mut b = mk();
        assert!(b.flush().is_none());
    }

    #[test]
    fn spans_cover_batch_exactly() {
        // property: spans partition [0, used)
        let mut b = DynamicBatcher::new(16, Duration::from_millis(1));
        let mut rng = crate::util::XorShift256::new(13);
        let mut batches = Vec::new();
        for id in 0..50u64 {
            let len = 1 + rng.below(9) as usize;
            let v: Vec<i64> = (0..len as i64).collect();
            batches.extend(b.offer(id, &v, &v));
        }
        batches.extend(b.flush());
        for batch in batches {
            let mut covered = 0;
            for (_, off, len, _) in &batch.spans {
                assert_eq!(*off, covered, "spans must be contiguous");
                covered += len;
            }
            assert_eq!(covered, batch.used);
        }
    }
}
