//! # RAPID — approximate pipelined soft multipliers & dividers
//!
//! Full-system reproduction of *RAPID: AppRoximAte Pipelined Soft
//! MultIpliers and Dividers for High-Throughput and Energy-Efficiency*
//! (Ebrahimi et al., IEEE TCAD 2022).
//!
//! The crate is organised in the layers DESIGN.md describes (see
//! ARCHITECTURE.md at the repo root for the cross-layer tour):
//!
//! * [`arith`] — bit-accurate functional models of every unit the paper
//!   builds or compares against (Mitchell, RAPID-G, MBM, INZeD, SIMDive,
//!   DRUM, AAXD, AFM, SAADI-EC, exact IPs).
//! * [`error`] — ARE / PRE / bias characterisation (exhaustive + Monte
//!   Carlo), reproducing the accuracy columns of Table III.
//! * [`circuit`] — the FPGA substrate: LUT6/CARRY4/FDRE netlists,
//!   technology mapping of each unit, static timing, switching-activity
//!   power and fine-grained pipelining (Fig. 4, resource/latency/power
//!   columns of Table III).
//! * [`apps`] — the three end-to-end applications (Pan-Tompkins QRS,
//!   JPEG compression, Harris corner tracking) over pluggable arithmetic
//!   (Figs. 5-12).
//! * [`explore`] — Pareto design-space exploration: enumerate the whole
//!   registry × width × pipeline grid, fuse circuit and accuracy halves,
//!   compute exact multi-objective frontiers and answer QoR budget
//!   queries (`rapid explore --app jpeg --qor "psnr>=30"`), with a
//!   successive-halving screen so the 16/32-bit sweeps stay tractable.
//! * `runtime` — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (HLO text produced by `python/compile/aot.py`). Behind the
//!   default-on `pjrt` cargo feature; `--no-default-features` builds are
//!   runtime-free and the PJRT-dependent tests/examples skip cleanly when
//!   `libxla` is absent (DESIGN.md §2).
//! * [`coordinator`] — the streaming orchestrator: sharded ingress lanes
//!   (dynamic batcher + worker pool each) with backpressure and deadline
//!   admission control, Prometheus-style metrics, the deterministic
//!   open-loop load harness (`rapid serve-bench`) and the pipeline
//!   scheduler.
//! * [`obs`] — structured span tracing: per-request lifecycle spans and
//!   per-batch/window/chunk spans into a lock-cheap per-thread recorder
//!   with a pluggable clock (monotonic for production, logical for
//!   bit-replayable traces), exported as Chrome trace-event JSON
//!   (`--trace`) and aggregated by `rapid trace-report`.
//! * [`util`] — zero-dependency PRNG/stats/CLI/bench/property-test helpers,
//!   including [`util::par`], the deterministic multi-core sweep engine
//!   every exhaustive/Monte-Carlo/power/equivalence sweep fans out on
//!   (`RAPID_THREADS` sets the worker count; results are bit-identical at
//!   every value).
//!
//! ## Quickstart
//!
//! ```no_run
//! // (no_run: on libxla-linked builds rustdoc test binaries miss the
//! // rpath; the same code runs in examples/quickstart.rs and the arith
//! // unit tests)
//! use rapid::arith::{ApproxMul, RapidMul};
//! let m = RapidMul::new(16, 10); // 16×16 multiplier, 10 coefficients
//! let p = m.mul(58, 18);
//! assert!((p as f64 - 1044.0).abs() / 1044.0 < 0.04);
//! ```

// Every public item carries rustdoc; CI builds docs with
// RUSTDOCFLAGS="-D warnings", which promotes any regression to an error.
#![warn(missing_docs)]

pub mod util;
pub mod arith;
pub mod error;
pub mod circuit;
pub mod apps;
pub mod explore;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod coordinator;
pub mod obs;
pub mod bench_support;

/// Commonly used items.
pub mod prelude {
    pub use crate::arith::{ApproxDiv, ApproxMul, DivUnit, MulUnit, RapidDiv, RapidMul};
    pub use crate::arith::registry::{make_div, make_mul};
    pub use crate::error::metrics::ErrorReport;
    pub use crate::util::XorShift256;
}
