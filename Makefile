# Convenience targets; tier-1 is `cargo build --release && cargo test -q`.

.PHONY: all test artifacts bench bench-hotpath bench-explore bench-emit bench-serve bench-governor emit-artifacts doc

all:
	cargo build --release

test:
	cargo build --release && cargo test -q

# Scheme JSONs (Rust is the source of truth) + AOT-lowered HLO artifacts.
# The python step needs jax with x64 enabled; see python/compile/aot.py.
artifacts:
	cargo run --release -- export-scheme --out artifacts/schemes
	cd python && python -m compile.aot --out-dir ../artifacts

bench:
	for b in fig1_motivation fig2_error_surface fig4_stage_balance \
	         fig8_fig9_qor fig10_apps fig11_fig12_pipeline \
	         table1_accuracy table3_mul table3_div ablations hotpath \
	         explore emit serve; do \
	    cargo bench --bench $$b; \
	done

# One-command refresh of the EXPERIMENTS.md §Perf rows (scalar vs batched
# unit throughput, sweeps, gate-level eval scalar vs compiled bit-parallel,
# PJRT path when artifacts exist). Also rewrites BENCH_hotpath.json.
bench-hotpath:
	cargo bench --bench hotpath

# Design-space exploration ladder (candidates/sec, survivor splits); also
# rewrites BENCH_explore.json and prints the width-8 accuracy-budget pick.
bench-explore:
	cargo bench --bench explore

# RTL export throughput (lowering, reparse round-trip, vector oracles);
# also rewrites BENCH_emit.json.
bench-emit:
	cargo bench --bench emit

# Open-loop serving saturation ladder (offered vs achieved, p50/p99/p999)
# over the sharded functional path; also rewrites BENCH_serve.json.
bench-serve:
	cargo bench --bench serve

# QoR-adaptive governed scenario (clean -> noisy -> clean through the
# rapid3 -> rapid10 -> exact ladder): switch trace, per-phase throughput
# and tail latency; also rewrites BENCH_governor.json.
bench-governor:
	cargo bench --bench governor

# The Table III trio as synthesizable RTL bundles (module + self-checking
# testbench + $readmemh vectors) under rtl/. With iverilog installed,
# each bundle self-checks:
#   cd rtl && iverilog -g2012 -o sim rapid10_mul16.sv rapid10_mul16_tb.sv && vvp sim
emit-artifacts:
	cargo run --release -- emit --unit rapid10 --op mul --width 16 --out rtl
	cargo run --release -- emit --unit rapid9 --op div --width 8 --out rtl
	cargo run --release -- emit --unit exact --op mul --width 16 --out rtl
	cargo run --release -- emit --unit rapid10 --op mul --width 16 --stages 4 --out rtl
	cargo run --release -- emit --unit rapid9 --op div --width 8 --stages 3 --out rtl

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
