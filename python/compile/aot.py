"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust (L3).

HLO *text* — not ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)  # kernels carry int64 mantissas

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower a single entry point")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, fn, example in model.entry_points():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
