"""Layer-2 JAX models: the compute graphs AOT-lowered for the Rust runtime.

Every entry point composes the Layer-1 Pallas kernels (``kernels.rapid``)
into the hot graphs the coordinator serves:

* ``batched_mul`` / ``batched_div``  — raw RAPID arithmetic over vectors
  (the paper's unit as a service; the L3 dynamic batcher feeds these).
* ``mac``                            — multiply-accumulate reduction, the
  inner loop shape of all three applications' kernels.
* ``conv3x3``                        — integer 3x3 convolution with RAPID
  multiplies: the Harris gradient / JPEG filter workload shape.
* ``pan_tompkins_energy``            — squaring + moving-window-integration
  stage of the QRS detector on int samples.

All graphs are integer-only and bit-exact mirrors of the Rust application
kernels, which lets ``rust/tests/pjrt_roundtrip.rs`` assert cross-layer
equality. Python never runs at serve time: ``aot.py`` lowers these once to
HLO text.
"""

import jax
import jax.numpy as jnp

from .kernels import rapid as K

# Fixed AOT shapes (the dynamic batcher pads to these).
BATCH = 8192
IMG = 64
WIN = 32


def batched_mul(a, b, grid, coeffs):
    """[BATCH] x [BATCH] -> [BATCH] RAPID-10 16-bit products."""
    return (K.rapid_mul_tables(a, b, grid, coeffs, width=16),)


def batched_div(a, b, grid, coeffs):
    """[BATCH] x [BATCH] -> [BATCH] RAPID-9 16/8 quotients."""
    return (K.rapid_div_tables(a, b, grid, coeffs, width=8),)


def mac(a, b, grid, coeffs):
    """Dot product with RAPID multiplies, exact accumulation -> [1]."""
    p = K.rapid_mul_tables(a, b, grid, coeffs, width=16)
    return (jnp.sum(p, keepdims=True),)


def conv3x3(img, kern, grid, coeffs):
    """[IMG, IMG] int32 image (x) 3x3 int kernel, RAPID multiplies.

    Same-padding is *not* applied: output is [IMG-2, IMG-2], matching the
    Rust mirror (`apps::fixed::conv3x3_rapid`). Products are computed by
    flattening each (pixel, tap) pair through the batched kernel so every
    multiply goes through the same RAPID datapath.
    """
    h = img.shape[0] - 2
    w = img.shape[1] - 2
    taps = []
    for dy in range(3):
        for dx in range(3):
            taps.append(jax.lax.dynamic_slice(img, (dy, dx), (h, w)))
    patches = jnp.stack(taps, axis=-1).astype(jnp.int64)  # [h, w, 9]
    kflat = kern.reshape(-1).astype(jnp.int64)  # [9]
    # sign-magnitude: RAPID units are unsigned (the paper's units are
    # unsigned; applications carry the sign separately)
    ka = jnp.abs(kflat)
    ks = jnp.sign(kflat)
    pa = jnp.abs(patches)
    ps = jnp.sign(patches)
    flat_a = jnp.broadcast_to(pa, (h, w, 9)).reshape(-1)
    flat_b = jnp.broadcast_to(ka, (h, w, 9)).reshape(-1)
    n = flat_a.shape[0]
    pad = (-n) % K.BLOCK
    flat_a = jnp.pad(flat_a, (0, pad))
    flat_b = jnp.pad(flat_b, (0, pad))
    prod = K.rapid_mul_tables(flat_a, flat_b, grid, coeffs, width=16)[: n]
    prod = prod.reshape(h, w, 9) * ps * ks
    return (jnp.sum(prod, axis=-1),)


def pan_tompkins_energy(sig, grid, coeffs):
    """Squaring + WIN-sample moving-window integration (QRS energy stage).

    sig: [BATCH] int32 bandpassed/derivative samples (signed). The square
    uses the RAPID multiplier on |x|; MWI is an exact windowed sum, like
    the adder-only hardware stage.
    """
    mag = jnp.abs(sig).astype(jnp.int64)
    sq = K.rapid_mul_tables(mag, mag, grid, coeffs, width=16)
    csum = jnp.cumsum(sq)
    shifted = jnp.pad(csum, (WIN, 0))[: csum.shape[0]]
    mwi = csum - shifted
    return (mwi,)


def entry_points():
    """(name, fn, example_args) for every artifact `aot.py` emits.

    Every artifact's trailing two parameters are the scheme tables
    (grid: int32[256], coeffs: int64[G]) — the Rust runtime loads them from
    `artifacts/schemes/*.json` and passes them on every call, so the
    compiled signature is deterministic (DESIGN.md §2).
    """
    i64 = jnp.int64
    v = jax.ShapeDtypeStruct((BATCH,), i64)
    img = jax.ShapeDtypeStruct((IMG, IMG), i64)
    kern = jax.ShapeDtypeStruct((3, 3), i64)
    grid = jax.ShapeDtypeStruct((256,), jnp.int32)
    mul_coeffs = jax.ShapeDtypeStruct((10,), i64)
    div_coeffs = jax.ShapeDtypeStruct((9,), i64)
    return [
        ("rapid_mul16", batched_mul, (v, v, grid, mul_coeffs)),
        ("rapid_div8", batched_div, (v, v, grid, div_coeffs)),
        ("rapid_mac16", mac, (v, v, grid, mul_coeffs)),
        ("conv3x3_rapid", conv3x3, (img, kern, grid, mul_coeffs)),
        ("pan_tompkins_energy", pan_tompkins_energy, (v, grid, mul_coeffs)),
    ]
