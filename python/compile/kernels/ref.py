"""Pure-numpy correctness oracles for the Pallas kernels.

Written independently of the kernel code path: the reference computes the
leading-one split with a binary-search bit ladder and reconstructs the
anti-log through arbitrary-precision Python ints, rather than reusing the
kernel's jnp integer pipeline. pytest asserts bit-equality between
``rapid.rapid_mul`` / ``rapid.rapid_div`` and these oracles across shape /
value sweeps, and additionally checks approximation quality against the
exact product / quotient.
"""

import numpy as np

from . import rapid as k


def _split_np(x, w):
    """(k, frac) of Eq. 2 using a numpy bit ladder (independent impl)."""
    x = np.asarray(x, dtype=np.uint64)
    kk = np.zeros_like(x, dtype=np.int64)
    t = x.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        big = t >= (np.uint64(1) << np.uint64(shift))
        kk = np.where(big, kk + shift, kk)
        t = np.where(big, t >> np.uint64(shift), t)
    low = (x - (np.uint64(1) << kk.astype(np.uint64))).astype(np.int64)
    frac = np.where(
        kk <= w,
        low << np.maximum(w - kk, 0),
        low >> np.maximum(kk - w, 0),
    )
    return kk, frac.astype(np.int64)


def _region_coeff(kind, width, groups, x1, x2, w):
    grid, coeffs = k.load_scheme(kind, width, groups)
    grid = np.asarray(grid)
    coeffs = np.asarray(coeffs)
    g = grid[(x1 >> (w - 4)) * 16 + (x2 >> (w - 4))]
    return coeffs[g]


def ref_mul(a, b, *, width=16, groups=10):
    """Oracle for rapid_mul on numpy int arrays."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    w = width - 1
    k1, x1 = _split_np(np.maximum(a, 1), w)
    k2, x2 = _split_np(np.maximum(b, 1), w)
    c = _region_coeff("mul", width, groups, x1, x2, w)
    one = 1 << w
    out = np.zeros_like(a)
    for idx in np.ndindex(a.shape):
        if a[idx] == 0 or b[idx] == 0:
            continue
        xs = int(x1[idx]) + int(x2[idx]) + int(c[idx])
        if xs < one:
            mant, e = one + xs, int(k1[idx]) + int(k2[idx])
        else:
            mant, e = min(xs, 2 * one - 1), int(k1[idx]) + int(k2[idx]) + 1
        out[idx] = (mant << e) >> w  # python ints: no overflow
    return out


def ref_div(a, b, *, width=16, groups=9):
    """Oracle for rapid_div on numpy int arrays."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    n = width
    w = n - 1
    k1, x1 = _split_np(np.maximum(a, 1), w)
    k2, x2 = _split_np(np.maximum(b, 1), w)
    c = _region_coeff("div", width, groups, x1, x2, w)
    one = 1 << w
    out = np.zeros_like(a)
    for idx in np.ndindex(a.shape):
        ai, bi = int(a[idx]), int(b[idx])
        if bi == 0:
            out[idx] = (1 << (2 * n)) - 1
            continue
        if ai == 0:
            continue
        if ai >= (bi << n):
            out[idx] = (1 << n) - 1
            continue
        if x1[idx] >= x2[idx]:
            mant0, e = one + int(x1[idx] - x2[idx]), int(k1[idx] - k2[idx])
        else:
            mant0, e = 2 * one - int(x2[idx] - x1[idx]), int(k1[idx] - k2[idx]) - 1
        mant = max(mant0 - int(c[idx]), 1)
        if e >= 0:
            out[idx] = (mant << e) >> w
        else:
            sh = w - e
            out[idx] = mant >> sh if sh < 64 else 0
    return out
