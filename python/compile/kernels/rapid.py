"""Layer-1 Pallas kernels: RAPID approximate multiply / divide.

The kernels are bit-exact ports of the Rust functional models
(``rust/src/arith/{mitchell,rapid}.rs``). They load the error-reduction
scheme (16x16 region grid + quantised coefficients) from the JSON files the
Rust side exports (``rapid export-scheme``), so both layers share identical
constants; the cross-layer integration test in ``rust/tests/`` checks
bit-equality through the PJRT runtime.

Hardware adaptation (DESIGN.md §2): the FPGA datapath (LOD -> align ->
ternary add -> shift) becomes a vectorised VPU pipeline. LOD is computed
with integer comparisons (XLA HLO has no CLZ); the casex coefficient mux
becomes a gather from a 256-entry group table; everything is elementwise,
so the kernel tiles cleanly into VMEM blocks via the pallas grid.

All kernels run with ``interpret=True``: real TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.
"""

import functools
import json
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Scheme files live next to the AOT artifacts; overridable for tests.
SCHEME_DIR = os.environ.get(
    "RAPID_SCHEME_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "schemes"),
)


@functools.lru_cache(maxsize=None)
def load_scheme(kind: str, width: int, groups: int):
    """Load an exported scheme: returns (grid[256] int32, coeffs[G] int64)."""
    path = os.path.join(SCHEME_DIR, f"{kind}{width}_g{groups}.json")
    with open(path) as f:
        data = json.load(f)
    assert data["kind"] == kind and data["width"] == width
    assert data["groups"] == groups and len(data["grid"]) == 256
    grid = jnp.asarray(data["grid"], dtype=jnp.int32)
    coeffs = jnp.asarray(data["coeffs"], dtype=jnp.int64)
    return grid, coeffs


def _lod(x, nbits):
    """floor(log2(x)) for x >= 1, via nbits-1 comparisons (vectorised)."""
    k = jnp.zeros_like(x)
    for i in range(1, nbits):
        k = k + (x >= (1 << i)).astype(x.dtype)
    return k


def _log_split(x, nbits, w):
    """Characteristic k and W-bit left-aligned fraction of x (Eq. 2)."""
    k = _lod(x, nbits)
    low = x - (jnp.ones_like(x) << k)
    # left-align: frac = low << (w - k); k <= nbits-1 <= w always for mul
    frac = jnp.where(k <= w, low << jnp.maximum(w - k, 0), low >> jnp.maximum(k - w, 0))
    return k, frac


def rapid_mul_math(a, b, *, width, grid, coeffs):
    """Bit-exact RAPID multiply on int64 tensors (values < 2^width)."""
    a = a.astype(jnp.int64)
    b = b.astype(jnp.int64)
    w = width - 1
    k1, x1 = _log_split(jnp.maximum(a, 1), width, w)
    k2, x2 = _log_split(jnp.maximum(b, 1), width, w)
    # region select: top-4 bits of each fraction -> 16x16 grid -> group
    i = x1 >> (w - 4)
    j = x2 >> (w - 4)
    group = jnp.take(grid, (i * 16 + j).astype(jnp.int32))
    c = jnp.take(coeffs, group)
    xs = x1 + x2 + c
    one = jnp.int64(1) << w
    carry = xs >= one
    mant = jnp.where(carry, jnp.minimum(xs, (one << 1) - 1), one + xs)
    e = k1 + k2 + carry.astype(jnp.int64)
    res = (mant << e) >> w
    return jnp.where((a == 0) | (b == 0), jnp.int64(0), res)


def rapid_div_math(a, b, *, width, grid, coeffs):
    """Bit-exact RAPID 2N-by-N divide on int64 tensors.

    ``width`` is the divisor width N; dividend a < 2^(2N). Saturation rules
    match the Rust model: b == 0 -> 2^(2N)-1; overflow -> 2^N - 1.
    """
    a = a.astype(jnp.int64)
    b = b.astype(jnp.int64)
    n = width
    w = n - 1
    k1, x1 = _log_split(jnp.maximum(a, 1), 2 * n, w)
    k2, x2 = _log_split(jnp.maximum(b, 1), n, w)
    i = x1 >> (w - 4)
    j = x2 >> (w - 4)
    group = jnp.take(grid, (i * 16 + j).astype(jnp.int32))
    c = jnp.take(coeffs, group)
    borrow = x1 < x2
    one = jnp.int64(1) << w
    mant0 = jnp.where(borrow, (one << 1) - (x2 - x1), one + (x1 - x2))
    e = k1 - k2 - borrow.astype(jnp.int64)
    mant = jnp.maximum(mant0 - c, 1)
    q = jnp.where(
        e >= 0,
        (mant << jnp.maximum(e, 0)) >> w,
        mant >> jnp.minimum(w - e, 63),
    )
    sat_all = (jnp.int64(1) << (2 * n)) - 1
    sat_n = (jnp.int64(1) << n) - 1
    q = jnp.where(a == 0, 0, q)
    q = jnp.where(a >= (b << n), sat_n, q)  # overflow rule
    q = jnp.where(b == 0, sat_all, q)
    return q


def _mul_kernel(a_ref, b_ref, grid_ref, coeff_ref, o_ref, *, width):
    o_ref[...] = rapid_mul_math(
        a_ref[...], b_ref[...], width=width, grid=grid_ref[...], coeffs=coeff_ref[...]
    )


def _div_kernel(a_ref, b_ref, grid_ref, coeff_ref, o_ref, *, width):
    o_ref[...] = rapid_div_math(
        a_ref[...], b_ref[...], width=width, grid=grid_ref[...], coeffs=coeff_ref[...]
    )


# VMEM block: 8192 int64 lanes x 3 tensors = 192 KiB << 16 MiB VMEM; chosen
# in DESIGN.md §Perf (leaves headroom for double buffering).
BLOCK = 8192


def rapid_mul_tables(a, b, grid_t, coeffs, *, width=16, block=BLOCK):
    """Batched RAPID multiply with the scheme tables as *traced arguments*.

    The AOT entry points thread the tables through as real parameters so
    every artifact has a deterministic signature (jax may otherwise hoist
    large captured constants into parameters for some graphs but not
    others). The tables' BlockSpec maps every grid step to the whole table
    — in VMEM they are a few hundred bytes pinned across the stream.
    """
    n = a.shape[0]
    assert n % block == 0 or n < block, f"batch {n} not tileable by {block}"
    blk = min(block, n)
    kernel = functools.partial(_mul_kernel, width=width)
    g = int(coeffs.shape[0])
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.int64),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a.astype(jnp.int64), b.astype(jnp.int64), grid_t, coeffs)


def rapid_mul(a, b, *, width=16, groups=10, block=BLOCK):
    """Convenience wrapper loading the scheme from disk (tests / eager)."""
    grid_t, coeffs = load_scheme("mul", width, groups)
    return rapid_mul_tables(a, b, grid_t, coeffs, width=width, block=block)


def rapid_div_tables(a, b, grid_t, coeffs, *, width=8, block=BLOCK):
    """Batched RAPID divide with the scheme tables as traced arguments."""
    n = a.shape[0]
    assert n % block == 0 or n < block, f"batch {n} not tileable by {block}"
    blk = min(block, n)
    kernel = functools.partial(_div_kernel, width=width)
    g = int(coeffs.shape[0])
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.int64),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(a.astype(jnp.int64), b.astype(jnp.int64), grid_t, coeffs)


def rapid_div(a, b, *, width=8, groups=9, block=BLOCK):
    """Convenience wrapper loading the scheme from disk (tests / eager)."""
    grid_t, coeffs = load_scheme("div", width, groups)
    return rapid_div_tables(a, b, grid_t, coeffs, width=width, block=block)
