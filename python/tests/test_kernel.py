"""L1 kernel vs oracle — the core build-time correctness signal.

Sweeps shapes, widths and value distributions (hand-rolled hypothesis-style
sweep: the offline image has no `hypothesis` package) and asserts
bit-equality between the Pallas kernels and the independent numpy oracles,
plus approximation-quality bounds against exact arithmetic.
"""

import os

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

from compile.kernels import rapid as K  # noqa: E402
from compile.kernels import ref  # noqa: E402

SCHEMES = os.path.join(K.SCHEME_DIR, "mul16_g10.json")
pytestmark = pytest.mark.skipif(
    not os.path.exists(SCHEMES),
    reason="scheme files missing - run `make artifacts` first",
)

RNG = np.random.default_rng(0xA91D)


def rand_ops(n, bits, rng=RNG):
    return rng.integers(0, 1 << bits, size=n, dtype=np.int64)


# ---------------------------------------------------------------- mul ----

@pytest.mark.parametrize("n", [64, 1000, 8192, 16384])
@pytest.mark.parametrize("groups", [3, 5, 10])
def test_mul_matches_oracle_shapes(n, groups):
    a = rand_ops(n, 16)
    b = rand_ops(n, 16)
    got = np.asarray(K.rapid_mul(jax.numpy.asarray(a), jax.numpy.asarray(b), width=16, groups=groups))
    want = ref.ref_mul(a, b, width=16, groups=groups)
    np.testing.assert_array_equal(got, want)


def test_mul_edge_values():
    edges = np.array([0, 1, 2, 3, 4, 5, 15, 16, 17, 127, 128, 255, 256,
                      32767, 32768, 65534, 65535], dtype=np.int64)
    a, b = np.meshgrid(edges, edges)
    a, b = a.ravel(), b.ravel()
    got = np.asarray(K.rapid_mul(jax.numpy.asarray(a), jax.numpy.asarray(b)))
    want = ref.ref_mul(a, b)
    np.testing.assert_array_equal(got, want)


def test_mul_zero_annihilates():
    a = rand_ops(256, 16)
    z = np.zeros(256, dtype=np.int64)
    got = np.asarray(K.rapid_mul(jax.numpy.asarray(a), jax.numpy.asarray(z)))
    assert (got == 0).all()


def test_mul_quality_vs_exact():
    a = rand_ops(20000, 16)
    b = rand_ops(20000, 16)
    nz = (a > 0) & (b > 0)
    a, b = a[nz][: K.BLOCK], b[nz][: K.BLOCK]  # keep a tileable batch
    got = np.asarray(K.rapid_mul(jax.numpy.asarray(a), jax.numpy.asarray(b))).astype(float)
    exact = (a * b).astype(float)
    rel = np.abs(exact - got) / exact
    assert rel.mean() < 0.01, f"ARE {rel.mean()}"   # paper band ~0.6 %
    assert rel.max() < 0.12, f"PRE {rel.max()}"


def test_mul_commutes():
    a = rand_ops(4096, 16)
    b = rand_ops(4096, 16)
    ab = np.asarray(K.rapid_mul(jax.numpy.asarray(a), jax.numpy.asarray(b)))
    ba = np.asarray(K.rapid_mul(jax.numpy.asarray(b), jax.numpy.asarray(a)))
    np.testing.assert_array_equal(ab, ba)


# ---------------------------------------------------------------- div ----

@pytest.mark.parametrize("n", [64, 1000, 8192])
@pytest.mark.parametrize("groups", [3, 5, 9])
def test_div_matches_oracle_shapes(n, groups):
    b = rand_ops(n, 8)
    a = rand_ops(n, 16)
    got = np.asarray(K.rapid_div(jax.numpy.asarray(a), jax.numpy.asarray(b), width=8, groups=groups))
    want = ref.ref_div(a, b, width=8, groups=groups)
    np.testing.assert_array_equal(got, want)


def test_div_edge_values():
    a = np.array([0, 1, 2, 255, 256, 4095, 65535, 300, 1000], dtype=np.int64)
    b = np.array([0, 1, 2, 3, 128, 255, 17, 90, 1], dtype=np.int64)
    got = np.asarray(K.rapid_div(jax.numpy.asarray(a), jax.numpy.asarray(b), width=8, groups=9))
    want = ref.ref_div(a, b, width=8, groups=9)
    np.testing.assert_array_equal(got, want)


def test_div_saturation_rules():
    a = np.array([123, 0, 65535], dtype=np.int64)
    b = np.array([0, 7, 1], dtype=np.int64)
    got = np.asarray(K.rapid_div(jax.numpy.asarray(a), jax.numpy.asarray(b), width=8, groups=9))
    assert got[0] == (1 << 16) - 1  # div by zero
    assert got[1] == 0
    assert got[2] == 255  # overflow saturates to N bits


def test_div_quality_vs_exact():
    b = rand_ops(40000, 8)
    a = rand_ops(40000, 16)
    ok = (b > 0) & (a >= b) & (a < (b << 8))
    a, b = a[ok][: K.BLOCK], b[ok][: K.BLOCK]  # keep a tileable batch
    got = np.asarray(K.rapid_div(jax.numpy.asarray(a), jax.numpy.asarray(b), width=8, groups=9)).astype(float)
    exact = (a // b).astype(float)
    rel = np.abs(exact - got) / exact
    assert rel.mean() < 0.02, f"ARE {rel.mean()}"


# ------------------------------------------------------------- widths ----

@pytest.mark.parametrize("width,bits", [(16, 16), (16, 12), (16, 8)])
def test_mul_narrow_value_ranges(width, bits):
    """Value-range sweep: operands drawn from sub-ranges of the width."""
    a = rand_ops(2048, bits)
    b = rand_ops(2048, bits)
    got = np.asarray(K.rapid_mul(jax.numpy.asarray(a), jax.numpy.asarray(b), width=width))
    want = ref.ref_mul(a, b, width=width)
    np.testing.assert_array_equal(got, want)


def test_block_boundaries():
    """Batch sizes around the pallas BLOCK boundary tile correctly."""
    for n in [K.BLOCK - 1, K.BLOCK, K.BLOCK * 2]:
        if n % K.BLOCK and n > K.BLOCK:
            continue
        a = rand_ops(n, 16)
        b = rand_ops(n, 16)
        got = np.asarray(K.rapid_mul(jax.numpy.asarray(a), jax.numpy.asarray(b)))
        want = ref.ref_mul(a, b)
        np.testing.assert_array_equal(got, want)
