"""L2 model tests: the AOT entry-point graphs against independent numpy
mirrors (the same mirrors the Rust cross-layer test uses), plus signature
checks that pin the artifact interface the runtime relies on."""

import os

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels import rapid as K  # noqa: E402
from compile.kernels import ref  # noqa: E402

SCHEMES = os.path.join(K.SCHEME_DIR, "mul16_g10.json")
pytestmark = pytest.mark.skipif(
    not os.path.exists(SCHEMES),
    reason="scheme files missing - run `make artifacts` first",
)

RNG = np.random.default_rng(7)


def tables(kind, width, groups):
    return K.load_scheme(kind, width, groups)


def test_batched_mul_entry_matches_ref():
    g, c = tables("mul", 16, 10)
    a = RNG.integers(0, 1 << 16, size=model.BATCH, dtype=np.int64)
    b = RNG.integers(0, 1 << 16, size=model.BATCH, dtype=np.int64)
    (out,) = model.batched_mul(jax.numpy.asarray(a), jax.numpy.asarray(b), g, c)
    np.testing.assert_array_equal(np.asarray(out), ref.ref_mul(a, b, width=16, groups=10))


def test_batched_div_entry_matches_ref():
    g, c = tables("div", 8, 9)
    a = RNG.integers(0, 1 << 16, size=model.BATCH, dtype=np.int64)
    b = RNG.integers(0, 1 << 8, size=model.BATCH, dtype=np.int64)
    (out,) = model.batched_div(jax.numpy.asarray(a), jax.numpy.asarray(b), g, c)
    np.testing.assert_array_equal(np.asarray(out), ref.ref_div(a, b, width=8, groups=9))


def test_mac_entry_is_sum_of_products():
    g, c = tables("mul", 16, 10)
    a = RNG.integers(0, 1 << 16, size=model.BATCH, dtype=np.int64)
    b = RNG.integers(0, 1 << 16, size=model.BATCH, dtype=np.int64)
    (out,) = model.mac(jax.numpy.asarray(a), jax.numpy.asarray(b), g, c)
    want = ref.ref_mul(a, b, width=16, groups=10).sum()
    assert np.asarray(out)[0] == want


def test_conv3x3_entry_matches_numpy_mirror():
    g, c = tables("mul", 16, 10)
    img = RNG.integers(0, 256, size=(model.IMG, model.IMG), dtype=np.int64)
    kern = np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], dtype=np.int64)
    (out,) = model.conv3x3(jax.numpy.asarray(img), jax.numpy.asarray(kern), g, c)
    h = model.IMG - 2
    want = np.zeros((h, h), dtype=np.int64)
    for dy in range(3):
        for dx in range(3):
            patch = img[dy : dy + h, dx : dx + h]
            prod = ref.ref_mul(np.abs(patch), np.full_like(patch, abs(kern[dy, dx])), width=16, groups=10)
            want += prod * np.sign(patch) * np.sign(kern[dy, dx])
    np.testing.assert_array_equal(np.asarray(out), want)


def test_conv3x3_negative_kernel_taps():
    """Sign-magnitude handling: a Sobel-like kernel with negative taps."""
    g, c = tables("mul", 16, 10)
    img = RNG.integers(0, 256, size=(model.IMG, model.IMG), dtype=np.int64)
    kern = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], dtype=np.int64)
    (out,) = model.conv3x3(jax.numpy.asarray(img), jax.numpy.asarray(kern), g, c)
    out = np.asarray(out)
    # flat image -> zero gradient
    flat = np.full((model.IMG, model.IMG), 77, dtype=np.int64)
    (zero_out,) = model.conv3x3(jax.numpy.asarray(flat), jax.numpy.asarray(kern), g, c)
    assert (np.asarray(zero_out) == 0).all()
    assert out.shape == (model.IMG - 2, model.IMG - 2)


def test_pan_tompkins_energy_matches_mirror():
    g, c = tables("mul", 16, 10)
    sig = RNG.integers(-2048, 2048, size=model.BATCH, dtype=np.int64)
    (out,) = model.pan_tompkins_energy(jax.numpy.asarray(sig), g, c)
    mag = np.abs(sig)
    sq = ref.ref_mul(mag, mag, width=16, groups=10)
    want = np.zeros_like(sq)
    acc = 0
    for i in range(len(sq)):
        acc += sq[i]
        if i >= model.WIN:
            acc -= sq[i - model.WIN]
        want[i] = acc
    np.testing.assert_array_equal(np.asarray(out), want)


def test_entry_points_signature_contract():
    """The runtime relies on: every entry's last two args are the tables."""
    eps = model.entry_points()
    assert {n for n, _, _ in eps} == {
        "rapid_mul16",
        "rapid_div8",
        "rapid_mac16",
        "conv3x3_rapid",
        "pan_tompkins_energy",
    }
    for name, _, args in eps:
        grid, coeffs = args[-2], args[-1]
        assert grid.shape == (256,), name
        assert str(grid.dtype) == "int32", name
        assert coeffs.shape[0] in (9, 10), name
        assert str(coeffs.dtype) == "int64", name
